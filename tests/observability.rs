//! Integration tests for the observability surface: end-to-end
//! request tracing through the wire API (flight recorder), the
//! `trace_get` / `metrics_export` RPCs, backpressure stats on the
//! subscription terminal frame, and the instrument-name registry
//! lint.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use rc3e::config::ClusterConfig;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::metrics::valid_instrument_name;
use rc3e::middleware::api::{
    ErrorCode, SpanBody, SubscribeRequest, SubscriptionFilter, Topic,
    TraceGetRequest,
};
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::{NodeId, TraceId};

struct Cloud {
    server: ManagementServer,
    _agents: Vec<NodeAgent>,
    client: Client,
    hv: Arc<Hypervisor>,
}

fn cloud() -> Cloud {
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut agents = Vec::new();
    for n in [NodeId(0), NodeId(1)] {
        let a = NodeAgent::spawn(Arc::clone(&hv), n, None).unwrap();
        server.register_agent(n, a.addr());
        agents.push(a);
    }
    let client = Client::connect(server.addr()).unwrap();
    Cloud {
        server,
        _agents: agents,
        client,
        hv,
    }
}

/// A single-device RSaaS cloud for the physical-lease +
/// `program_full` job path.
fn rsaas_cloud() -> (ManagementServer, Client, Arc<Hypervisor>) {
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let client = Client::connect(server.addr()).unwrap();
    (server, client, hv)
}

/// Assert the span set forms exactly one connected tree rooted at an
/// RPC span: one root, every other span's parent present in the set.
fn assert_connected(spans: &[SpanBody]) {
    assert!(!spans.is_empty());
    let ids: HashSet<_> = spans.iter().map(|s| s.span).collect();
    let roots: Vec<&SpanBody> =
        spans.iter().filter(|s| s.parent.is_none()).collect();
    assert_eq!(
        roots.len(),
        1,
        "expected one root, got {:?}",
        roots.iter().map(|s| &s.name).collect::<Vec<_>>()
    );
    assert!(
        roots[0].name.starts_with("rpc."),
        "root span is {}, not an RPC span",
        roots[0].name
    );
    for s in spans {
        if let Some(p) = s.parent {
            assert!(
                ids.contains(&p),
                "span {} ({}) has orphaned parent {p}",
                s.span,
                s.name
            );
        }
    }
}

fn names_of(spans: &[SpanBody]) -> HashSet<&str> {
    spans.iter().map(|s| s.name.as_str()).collect()
}

// ================================================= end-to-end trace

/// One client-minted trace covers allocate → program → stream across
/// three RPCs; the async stream job adopts the submitter's trace and
/// `trace_get { job }` resolves the whole connected tree.
#[test]
fn wire_driven_flow_yields_one_connected_span_tree() {
    let mut c = cloud();
    // Untraced preamble — must not pollute the trace under test.
    let user = c.client.add_user("tracer").unwrap().user;
    let trace = c.client.start_trace();
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    c.client
        .program_core(user, lease.alloc, "matmul16")
        .unwrap();
    let job = c
        .client
        .stream(user, lease.alloc, "matmul16", 256)
        .unwrap()
        .job;
    // Wait for the job to settle (success needs rc2f artifacts; the
    // span tree is recorded either way).
    let body = loop {
        match c.client.job_wait(job, Some(30.0)) {
            Ok(b) if b.is_terminal() => break b,
            Ok(_) => {}
            Err(e) if e.code == ErrorCode::Timeout => {}
            Err(e) => panic!("job_wait: {e}"),
        }
    };
    // The job body advertises the trace it ran under.
    assert_eq!(body.trace, Some(trace));
    // Stop stamping the envelope so trace_get does not append itself.
    c.client.set_trace_context(None);
    let resp = c
        .client
        .trace_get(&TraceGetRequest::by_job(job))
        .unwrap();
    assert_eq!(resp.trace, trace);
    assert_eq!(resp.truncated, 0);
    assert_connected(&resp.spans);
    let names = names_of(&resp.spans);
    // RPC roots for each call in the workflow joined the same trace.
    for expect in [
        "rpc.alloc_vfpga",
        "rpc.program_core",
        "rpc.stream",
        "sched.admit",
        "hv.program",
        "bitstream.load",
        "fpga.pr",
        "job.stream",
    ] {
        assert!(names.contains(expect), "missing span {expect}");
    }
    if rc3e::testing::artifacts_available("observability") {
        assert!(names.contains("rc2f.stream"));
    }
    // The worker's adoption span hangs off the submitting RPC span.
    let by_name: HashMap<&str, &SpanBody> =
        resp.spans.iter().map(|s| (s.name.as_str(), s)).collect();
    assert_eq!(
        by_name["job.stream"].parent,
        Some(by_name["rpc.stream"].span)
    );
    // Completed spans carry durations and an outcome label.
    for s in &resp.spans {
        assert!(["ok", "error", "open"].contains(&s.outcome.as_str()));
    }
    // `trace_get { trace }` resolves the same tree.
    let by_trace = c
        .client
        .trace_get(&TraceGetRequest::by_trace(trace))
        .unwrap();
    assert_eq!(by_trace.spans.len(), resp.spans.len());
}

/// The RSaaS full-device path: `program_full` runs as an async job
/// whose worker thread adopts the submitting RPC's trace.
#[test]
fn program_full_job_inherits_the_submitters_trace() {
    let (_server, mut c, _hv) = rsaas_cloud();
    let user = c.add_user("rs").unwrap().user;
    let trace = c.start_trace();
    let lease = c.alloc_physical(user).unwrap();
    let job = c
        .program_full(user, lease.alloc, Some("my_design"))
        .unwrap()
        .job;
    c.job_wait_done(job).unwrap();
    c.set_trace_context(None);
    let resp =
        c.trace_get(&TraceGetRequest::by_job(job)).unwrap();
    assert_eq!(resp.trace, trace);
    assert_connected(&resp.spans);
    let names = names_of(&resp.spans);
    for expect in [
        "rpc.alloc_physical",
        "rpc.program_full",
        "job.program_full",
        "hv.full_config",
        "bitstream.load",
    ] {
        assert!(names.contains(expect), "missing span {expect}");
    }
}

/// Lookups that cannot resolve fail cleanly.
#[test]
fn trace_get_unknown_trace_is_a_bad_request() {
    let (_server, mut c, _hv) = rsaas_cloud();
    let err = c
        .trace_get(&TraceGetRequest::by_trace(TraceId(0xDEAD_BEEF)))
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
}

// ======================================================== metrics

/// `metrics_export` returns every instrument; histograms carry their
/// full bucket geometry (bounds, per-bucket counts, overflow).
#[test]
fn metrics_export_carries_bucket_bounds() {
    let mut c = cloud();
    let user = c.client.add_user("m").unwrap().user;
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    c.client.release(lease.alloc).unwrap();
    let snap = c.client.metrics_export().unwrap();
    assert!(!snap.counters.is_empty());
    assert!(!snap.gauges.is_empty());
    assert!(!snap.histograms.is_empty());
    for (name, h) in &snap.histograms {
        assert!(
            !h.bounds_us.is_empty(),
            "{name} exported without bucket bounds"
        );
        assert_eq!(
            h.bounds_us.len(),
            h.buckets.len(),
            "{name}: bounds/buckets arity mismatch"
        );
        // Bounds strictly increase; totals reconcile.
        assert!(h.bounds_us.windows(2).all(|w| w[0] < w[1]));
        let in_buckets: u64 =
            h.buckets.iter().sum::<u64>() + h.overflow;
        assert_eq!(in_buckets, h.count, "{name}: lost samples");
    }
    // The scheduler's admission telemetry shows up by name.
    assert!(snap
        .counters
        .iter()
        .any(|(n, v)| n == "sched.granted" && *v > 0));
}

/// Tier-1 lint: every registered instrument name is dot-separated
/// snake_case and no name is registered twice (across kinds).
#[test]
fn instrument_names_are_unique_and_snake_case() {
    let c = cloud();
    // Exercise enough surface that lazily-created instruments exist.
    let _ = Client::connect(c.server.addr()).unwrap().hello();
    let names = c.hv.metrics.names();
    assert!(!names.is_empty());
    let mut seen = HashSet::new();
    for (name, kind) in &names {
        assert!(
            valid_instrument_name(name),
            "instrument '{name}' ({kind:?}) is not dot-separated \
             snake_case"
        );
        assert!(
            seen.insert(name.clone()),
            "instrument '{name}' registered more than once"
        );
    }
}

// =================================================== backpressure

/// The subscription's terminal frame reports delivery stats so
/// clients can see drops and queue high-water without a second RPC.
#[test]
fn subscribe_terminal_frame_carries_backpressure_stats() {
    let mut c = cloud();
    let user = c.client.add_user("bp").unwrap().user;
    let addr = c.server.addr();
    let driver = std::thread::spawn(move || {
        let mut d = Client::connect(addr).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(300));
        let lease = d.alloc_vfpga(user, None, None).unwrap();
        d.release(lease.alloc).unwrap();
    });
    let mut stream = c
        .client
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::topic(Topic::Sched),
            lease: None,
            max_events: Some(2),
            timeout_s: Some(30.0),
            from_cursor: None,
        })
        .unwrap();
    let mut delivered = 0u64;
    for frame in stream.by_ref() {
        frame.unwrap();
        delivered += 1;
    }
    let stats = stream
        .stats()
        .expect("terminal frame carried no stats object")
        .clone();
    drop(stream);
    driver.join().unwrap();
    assert_eq!(stats.get("delivered").as_u64(), Some(delivered));
    assert_eq!(stats.get("dropped").as_u64(), Some(0));
    assert!(stats.get("queue_high_water").as_u64().is_some());
    // The registry-level fanout telemetry rides metrics_export.
    let snap = c.client.metrics_export().unwrap();
    assert!(snap
        .gauges
        .iter()
        .any(|(n, _)| n == "events.queue.high_water"));
}
