//! Integration tests for the protocol-4 data plane: out-of-band
//! binary wire frames (`[len|BIN][flags][seq][payload]`) and the
//! `stream.emit_output` path that carries RC2F stream output over
//! them — with the protocol-3 base64 JSON fallback producing
//! byte-identical payloads.

use std::sync::Arc;

use rc3e::hypervisor::Hypervisor;
use rc3e::middleware::proto::{
    read_frame, read_wire_frame, write_bin_chunk, write_bin_frame,
    write_frame, BinFrame, WireFrame, BIN_FLAG_END, MAX_FRAME,
};
use rc3e::middleware::{Client, ManagementServer, StreamFrame};
use rc3e::util::clock::VirtualClock;
use rc3e::util::json::Json;

/// Deterministic payload pattern (cheap, position-dependent).
fn pattern(size: usize) -> Vec<u8> {
    (0..size)
        .map(|i| (i as u8).wrapping_mul(31).wrapping_add(7))
        .collect()
}

// ================================================== binary framing

#[test]
fn bin_frame_roundtrip_across_sizes_including_empty_and_max() {
    // Sizes straddle the header length, typical chunk sizes and both
    // limits of the accepted payload range.
    for size in
        [0usize, 1, 8, 9, 255, 4096, 65536, MAX_FRAME as usize]
    {
        let payload = pattern(size);
        let mut buf = Vec::new();
        write_bin_frame(&mut buf, &BinFrame::data(7, payload.clone()))
            .unwrap();
        let mut r: &[u8] = &buf;
        match read_wire_frame(&mut r).unwrap().unwrap() {
            WireFrame::Bin(b) => {
                assert_eq!(b.flags, 0, "size {size}");
                assert_eq!(b.seq, 7, "size {size}");
                assert!(!b.is_end());
                assert_eq!(b.payload, payload, "size {size}");
            }
            WireFrame::Json(v) => panic!("json frame back: {v}"),
        }
        // Clean EOF after the single frame.
        assert!(read_wire_frame(&mut r).unwrap().is_none());
    }
}

#[test]
fn end_marker_roundtrips_with_flag_and_no_payload() {
    let mut buf = Vec::new();
    write_bin_frame(&mut buf, &BinFrame::end_marker(42)).unwrap();
    let mut r: &[u8] = &buf;
    match read_wire_frame(&mut r).unwrap().unwrap() {
        WireFrame::Bin(b) => {
            assert_eq!(b.flags, BIN_FLAG_END);
            assert!(b.is_end());
            assert_eq!(b.seq, 42);
            assert!(b.payload.is_empty());
        }
        WireFrame::Json(v) => panic!("json frame back: {v}"),
    }
}

#[test]
fn binary_and_json_frames_interleave_on_one_connection() {
    // A v4 multi-frame response mixes both framings on one byte
    // stream; the reader must hand each back in order.
    let mut buf = Vec::new();
    let header = Json::obj(vec![("stream", Json::from(true))]);
    write_frame(&mut buf, &header).unwrap();
    write_bin_frame(&mut buf, &BinFrame::data(1, pattern(1000)))
        .unwrap();
    write_bin_frame(&mut buf, &BinFrame::end_marker(2)).unwrap();
    let terminal = StreamFrame::terminal(3, None);
    write_frame(&mut buf, &terminal.to_json()).unwrap();

    let mut r: &[u8] = &buf;
    assert!(matches!(
        read_wire_frame(&mut r).unwrap().unwrap(),
        WireFrame::Json(_)
    ));
    match read_wire_frame(&mut r).unwrap().unwrap() {
        WireFrame::Bin(b) => {
            assert_eq!(b.seq, 1);
            assert_eq!(b.payload, pattern(1000));
        }
        WireFrame::Json(v) => panic!("json frame back: {v}"),
    }
    match read_wire_frame(&mut r).unwrap().unwrap() {
        WireFrame::Bin(b) => assert!(b.is_end()),
        WireFrame::Json(v) => panic!("json frame back: {v}"),
    }
    match read_wire_frame(&mut r).unwrap().unwrap() {
        WireFrame::Json(v) => {
            let f = StreamFrame::from_json(&v).unwrap();
            assert!(f.end);
            assert_eq!(f.seq, 3);
        }
        WireFrame::Bin(_) => panic!("binary frame back"),
    }
    assert!(read_wire_frame(&mut r).unwrap().is_none());
}

#[test]
fn malformed_binary_frames_are_rejected() {
    const BIN: u32 = 0x8000_0000;
    // Declared length shorter than the flags+seq header.
    let mut buf = Vec::new();
    buf.extend_from_slice(&(4u32 | BIN).to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]);
    let mut r: &[u8] = &buf;
    assert!(read_wire_frame(&mut r).is_err());

    // Declared payload above the limit: rejected from the length
    // word alone, before any payload allocation.
    let mut buf = Vec::new();
    buf.extend_from_slice(&((9 + MAX_FRAME + 1) | BIN).to_le_bytes());
    let mut r: &[u8] = &buf;
    assert!(read_wire_frame(&mut r).is_err());

    // Truncated mid-payload: hard error, not a clean EOF.
    let mut buf = Vec::new();
    write_bin_frame(&mut buf, &BinFrame::data(1, pattern(64)))
        .unwrap();
    buf.truncate(buf.len() - 10);
    let mut r: &[u8] = &buf;
    assert!(read_wire_frame(&mut r).is_err());

    // The writer refuses oversized payloads symmetrically.
    let huge = vec![0u8; MAX_FRAME as usize + 1];
    let mut sink = Vec::new();
    assert!(write_bin_chunk(&mut sink, 0, 1, &huge).is_err());
}

#[test]
fn pre_v4_reader_rejects_binary_frames() {
    // `read_frame` is the pre-v4 entry point: a binary frame there
    // means the peer skipped negotiation — protocol error.
    let mut buf = Vec::new();
    write_bin_frame(&mut buf, &BinFrame::data(1, vec![1, 2, 3]))
        .unwrap();
    let mut r: &[u8] = &buf;
    assert!(read_frame(&mut r).is_err());
}

// ============================================ end-to-end data plane

#[test]
fn v3_fallback_delivers_byte_identical_output() {
    let dir = rc3e::runtime::artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping data-plane test: run `make artifacts`");
        return;
    }
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();

    // A protocol-4 client receives the payload as binary frames.
    let mut c4 = Client::connect(server.addr()).unwrap();
    assert_eq!(c4.proto(), 4);
    let user = c4.add_user("dp").unwrap().user;
    let lease = c4.alloc_vfpga(user, None, None).unwrap();
    c4.program_core(user, lease.alloc, "matmul16").unwrap();
    let mut out4 = Vec::new();
    let body4 = c4
        .stream_data(user, lease.alloc, "matmul16", 512, &mut out4)
        .unwrap();
    assert_eq!(out4.len() as u64, body4.output_bytes);
    // 512 mults of 16x16 f32 results.
    assert_eq!(out4.len(), 512 * 16 * 16 * 4);
    assert_eq!(body4.validation_failures, 0);

    // A protocol-3 client on the same lease gets the same bytes via
    // base64 `stream_data` events inside JSON frames.
    let token = c4.lease_token(lease.alloc).unwrap();
    let mut c3 = Client::connect(server.addr()).unwrap();
    c3.set_proto(3);
    assert_eq!(c3.proto(), 3);
    c3.set_lease_token(lease.alloc, token);
    let mut out3 = Vec::new();
    let body3 = c3
        .stream_data(user, lease.alloc, "matmul16", 512, &mut out3)
        .unwrap();
    assert_eq!(out3, out4, "fallback payload differs from binary");
    assert_eq!(body3.checksum, body4.checksum);
    assert_eq!(body3.output_bytes, body4.output_bytes);

    // The connections return to request/response mode afterwards.
    assert!(c4.hello().is_ok());
    assert!(c3.hello().is_ok());
    c4.release(lease.alloc).unwrap();
}

#[test]
fn stream_data_failure_arrives_as_a_single_json_error() {
    // Unknown core: the server answers with one non-streaming error
    // frame before any header — no artifacts needed.
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let user = c.add_user("dp-err").unwrap().user;
    let lease = c.alloc_vfpga(user, None, None).unwrap();
    let mut out = Vec::new();
    let err = c
        .stream_data(user, lease.alloc, "no_such_core", 64, &mut out)
        .unwrap_err();
    assert!(out.is_empty());
    // The connection survives the refusal.
    assert!(c.hello().is_ok(), "connection broken after {err}");
    c.release(lease.alloc).unwrap();
}
