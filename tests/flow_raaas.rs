//! Fig. 3 integration test: the full middleware → RC3E → RC2F
//! interaction for a RAaaS user, over the real TCP middleware.
//!
//! Sequence (paper Fig. 3): allocate vFPGA → program (PR) →
//! initialize (status/ucs) → execute (stream) → release — plus the
//! bookkeeping assertions the figure implies at each arrow.

use std::sync::Arc;

use rc3e::hypervisor::Hypervisor;
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::NodeId;
use rc3e::util::json::Json;

fn artifacts_present() -> bool {
    // Logs an explicit "skipped: artifacts missing" line when absent.
    rc3e::testing::artifacts_available("flow_raaas")
}

struct Cloud {
    _server: ManagementServer,
    _agents: Vec<NodeAgent>,
    client: Client,
    hv: Arc<Hypervisor>,
    clock: Arc<VirtualClock>,
}

fn cloud() -> Cloud {
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut agents = Vec::new();
    for n in [NodeId(0), NodeId(1)] {
        let a = NodeAgent::spawn(Arc::clone(&hv), n, None).unwrap();
        server.register_agent(n, a.addr());
        agents.push(a);
    }
    let client = Client::connect(server.addr()).unwrap();
    Cloud {
        _server: server,
        _agents: agents,
        client,
        hv,
        clock,
    }
}

#[test]
fn fig3_interaction_flow() {
    let mut c = cloud();

    // -- middleware: create the user ------------------------------
    let user = c
        .client
        .call("add_user", Json::obj(vec![("name", Json::from("alice"))]))
        .unwrap()
        .get("user")
        .as_str()
        .unwrap()
        .to_string();

    // -- arrow 1: resource allocation ------------------------------
    let lease = c
        .client
        .call(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from(user.as_str()))]),
        )
        .unwrap();
    let alloc = lease.get("alloc").as_str().unwrap().to_string();
    let vfpga = lease.get("vfpga").as_str().unwrap().to_string();
    // DB reflects the lease.
    {
        let db = c.hv.db.lock().unwrap();
        let v = rc3e::util::ids::VfpgaId::parse(&vfpga).unwrap();
        assert!(db.owner_of(v).is_some());
    }

    // -- arrow 2: programming (PR through sanity checker) ----------
    let prog = c
        .client
        .call(
            "program_core",
            Json::obj(vec![
                ("user", Json::from(user.as_str())),
                ("alloc", Json::from(alloc.as_str())),
                ("core", Json::from("matmul16")),
            ]),
        )
        .unwrap();
    assert!(prog.get("pr_ms").as_f64().unwrap() > 700.0);

    // -- arrow 3: initialization (status via the node agent) -------
    let st = c
        .client
        .call(
            "status",
            Json::obj(vec![(
                "fpga",
                Json::from(lease.get("fpga").as_str().unwrap()),
            )]),
        )
        .unwrap();
    assert_eq!(st.get("regions_configured").as_u64(), Some(1));
    assert_eq!(st.get("regions_clocked").as_u64(), Some(1));

    // -- arrow 4: execution (streaming through the core) -----------
    if artifacts_present() {
        let out = c
            .client
            .call(
                "stream",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                    ("mults", Json::from(512u64)),
                ]),
            )
            .unwrap();
        assert_eq!(out.get("validation_failures").as_u64(), Some(0));
        assert!(out.get("virtual_mbps").as_f64().unwrap() > 450.0);
    }

    // -- arrow 5: release -------------------------------------------
    c.client
        .call(
            "release",
            Json::obj(vec![("alloc", Json::from(alloc.as_str()))]),
        )
        .unwrap();
    let st = c
        .client
        .call(
            "status",
            Json::obj(vec![(
                "fpga",
                Json::from(lease.get("fpga").as_str().unwrap()),
            )]),
        )
        .unwrap();
    assert_eq!(st.get("regions_configured").as_u64(), Some(0));
    assert_eq!(st.get("regions_clocked").as_u64(), Some(0));
}

#[test]
fn two_users_do_not_interfere() {
    let mut c = cloud();
    let mut ids = Vec::new();
    for name in ["alice", "bob"] {
        let user = c
            .client
            .call("add_user", Json::obj(vec![("name", Json::from(name))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .client
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        ids.push((
            user,
            lease.get("alloc").as_str().unwrap().to_string(),
            lease.get("vfpga").as_str().unwrap().to_string(),
        ));
    }
    // Distinct vFPGAs.
    assert_ne!(ids[0].2, ids[1].2);
    // Bob cannot program alice's lease.
    let err = c
        .client
        .call(
            "program_core",
            Json::obj(vec![
                ("user", Json::from(ids[1].0.as_str())),
                ("alloc", Json::from(ids[0].1.as_str())),
                ("core", Json::from("matmul16")),
            ]),
        )
        .unwrap_err();
    assert!(err.contains("not found or not yours"), "{err}");
    // Alice still can.
    c.client
        .call(
            "program_core",
            Json::obj(vec![
                ("user", Json::from(ids[0].0.as_str())),
                ("alloc", Json::from(ids[0].1.as_str())),
                ("core", Json::from("matmul16")),
            ]),
        )
        .unwrap();
}

#[test]
fn migration_preserves_service_over_rpc() {
    let mut c = cloud();
    let user = c
        .client
        .call("add_user", Json::obj(vec![("name", Json::from("m"))]))
        .unwrap()
        .get("user")
        .as_str()
        .unwrap()
        .to_string();
    let lease = c
        .client
        .call(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from(user.as_str()))]),
        )
        .unwrap();
    let alloc = lease.get("alloc").as_str().unwrap().to_string();
    c.client
        .call(
            "program_core",
            Json::obj(vec![
                ("user", Json::from(user.as_str())),
                ("alloc", Json::from(alloc.as_str())),
                ("core", Json::from("matmul16")),
            ]),
        )
        .unwrap();
    let mig = c
        .client
        .call(
            "migrate",
            Json::obj(vec![
                ("user", Json::from(user.as_str())),
                ("alloc", Json::from(alloc.as_str())),
            ]),
        )
        .unwrap();
    assert_ne!(
        mig.get("from").as_str().unwrap(),
        mig.get("to").as_str().unwrap()
    );
    // Still streamable at the new location.
    if artifacts_present() {
        let out = c
            .client
            .call(
                "stream",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                    ("mults", Json::from(256u64)),
                ]),
            )
            .unwrap();
        assert_eq!(out.get("validation_failures").as_u64(), Some(0));
    }
}

#[test]
fn virtual_clock_is_consistent_across_surfaces() {
    let mut c = cloud();
    let t0 = c.clock.now();
    c.client.call("hello", Json::obj(vec![])).unwrap();
    // One RPC = one 69 ms charge, visible on the shared clock.
    let d = c.clock.since(t0).as_millis_f64();
    assert!((d - 69.0).abs() < 0.5, "{d}");
}
