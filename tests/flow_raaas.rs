//! Fig. 3 integration test: the full middleware → RC3E → RC2F
//! interaction for a RAaaS user, over the real TCP middleware.
//!
//! Sequence (paper Fig. 3): allocate vFPGA → program (PR) →
//! initialize (status/ucs) → execute (stream) → release — plus the
//! bookkeeping assertions the figure implies at each arrow. Runs on
//! the typed protocol-3 client: every mutating call carries the
//! capability lease token the alloc returned.

use std::sync::Arc;

use rc3e::hypervisor::Hypervisor;
use rc3e::middleware::api::ErrorCode;
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::NodeId;

fn artifacts_present() -> bool {
    // Logs an explicit "skipped: artifacts missing" line when absent.
    rc3e::testing::artifacts_available("flow_raaas")
}

struct Cloud {
    _server: ManagementServer,
    _agents: Vec<NodeAgent>,
    client: Client,
    hv: Arc<Hypervisor>,
    clock: Arc<VirtualClock>,
}

fn cloud() -> Cloud {
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut agents = Vec::new();
    for n in [NodeId(0), NodeId(1)] {
        let a = NodeAgent::spawn(Arc::clone(&hv), n, None).unwrap();
        server.register_agent(n, a.addr());
        agents.push(a);
    }
    let client = Client::connect(server.addr()).unwrap();
    Cloud {
        _server: server,
        _agents: agents,
        client,
        hv,
        clock,
    }
}

#[test]
fn fig3_interaction_flow() {
    let mut c = cloud();

    // -- middleware: create the user ------------------------------
    let user = c.client.add_user("alice").unwrap().user;

    // -- arrow 1: resource allocation ------------------------------
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    // DB reflects the lease.
    {
        let db = c.hv.db.lock().unwrap();
        assert!(db.owner_of(lease.vfpga).is_some());
    }

    // -- arrow 2: programming (PR through sanity checker) ----------
    let prog = c
        .client
        .program_core(user, lease.alloc, "matmul16")
        .unwrap();
    assert!(prog.pr_ms > 700.0);

    // -- arrow 3: initialization (status via the node agent) -------
    let st = c.client.status(lease.fpga).unwrap();
    assert_eq!(st.regions_configured, 1);
    assert_eq!(st.regions_clocked, 1);

    // -- arrow 4: execution (streaming through the core) -----------
    if artifacts_present() {
        let out = c
            .client
            .stream_sync(user, lease.alloc, "matmul16", 512)
            .unwrap();
        assert_eq!(out.validation_failures, 0);
        assert!(out.virtual_mbps > 450.0);
    }

    // -- arrow 5: release -------------------------------------------
    assert!(c.client.release(lease.alloc).unwrap().released);
    let st = c.client.status(lease.fpga).unwrap();
    assert_eq!(st.regions_configured, 0);
    assert_eq!(st.regions_clocked, 0);
}

#[test]
fn two_users_do_not_interfere() {
    let mut c = cloud();
    let alice = c.client.add_user("alice").unwrap().user;
    let bob = c.client.add_user("bob").unwrap().user;
    let alice_lease = c.client.alloc_vfpga(alice, None, None).unwrap();
    // Bob connects separately and never learns alice's token.
    let mut bob_client = Client::connect(c._server.addr()).unwrap();
    let bob_lease =
        bob_client.alloc_vfpga(bob, None, None).unwrap();
    // Distinct vFPGAs.
    assert_ne!(alice_lease.vfpga, bob_lease.vfpga);
    // Bob cannot program alice's lease: no capability token.
    let err = bob_client
        .program_core(bob, alice_lease.alloc, "matmul16")
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadToken);
    // Alice still can.
    c.client
        .program_core(alice, alice_lease.alloc, "matmul16")
        .unwrap();
}

#[test]
fn migration_preserves_service_over_rpc() {
    let mut c = cloud();
    let user = c.client.add_user("m").unwrap().user;
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    c.client
        .program_core(user, lease.alloc, "matmul16")
        .unwrap();
    let mig = c.client.migrate(user, lease.alloc).unwrap();
    assert_ne!(mig.from, mig.to);
    // Still streamable at the new location.
    if artifacts_present() {
        let out = c
            .client
            .stream_sync(user, lease.alloc, "matmul16", 256)
            .unwrap();
        assert_eq!(out.validation_failures, 0);
    }
}

#[test]
fn virtual_clock_is_consistent_across_surfaces() {
    let mut c = cloud();
    let t0 = c.clock.now();
    c.client.hello().unwrap();
    // One RPC = one 69 ms charge, visible on the shared clock.
    let d = c.clock.since(t0).as_millis_f64();
    assert!((d - 69.0).abs() < 0.5, "{d}");
}
