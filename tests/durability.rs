//! Crash-injection durability test: the full out-of-process loop.
//!
//! Spawns the real `rc3e serve --state DIR` binary, drives an
//! admission storm over the wire, SIGKILLs the server mid-flight
//! (nothing graceful — exactly the crash the journal exists for),
//! restarts it on the same state directory and asserts:
//!
//! * live leases were **re-adopted**: the pre-crash capability tokens
//!   still validate and release cleanly through the hypervisor;
//! * no double grants: a released lease cannot be released again;
//! * grant counts match across the crash (re-adopted = kept live);
//! * event cursors resume exactly-once: a `from_cursor=1` replay
//!   after the restart starts with byte-for-byte the cursor sequence
//!   seen before the crash (no gaps, no duplicates, no reuse).
//!
//! The state directory honors `RC3E_DURABILITY_STATE` so CI can run
//! the test twice over one directory (cold boot, then
//! restart-from-existing-state); unset, it uses a fresh temp dir.
//! All counting assertions are relative to the baseline observed at
//! connect time, so pre-existing recovered state never trips them.

use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use rc3e::middleware::api::{
    QuotaSetRequest, SubscribeRequest, SubscriptionFilter,
};
use rc3e::middleware::Client;

struct Server {
    child: Child,
    addr: SocketAddr,
}

fn spawn_server(dir: &Path) -> Server {
    let mut child = Command::new(env!("CARGO_BIN_EXE_rc3e"))
        .arg("serve")
        .arg("--state")
        .arg(dir)
        .args(["--timescale", "0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn rc3e serve");
    let stdout = child.stdout.take().unwrap();
    let addr_line = BufReader::new(stdout)
        .lines()
        .next()
        .expect("server exited before printing its address")
        .expect("read server stdout");
    let addr = addr_line.trim().parse().expect("server address");
    Server { child, addr }
}

fn state_dir() -> PathBuf {
    match std::env::var("RC3E_DURABILITY_STATE") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => {
            let dir = std::env::temp_dir()
                .join(format!("rc3e-durability-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            dir
        }
    }
}

/// Replay every publicly-visible journaled event from cursor 1 and
/// return the cursor sequence (a ~1 s live window closes the stream).
fn drain_cursors(client: &mut Client) -> Vec<u64> {
    let stream = client
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::all(),
            lease: None,
            max_events: None,
            timeout_s: Some(1.0),
            from_cursor: Some(1),
        })
        .expect("subscribe");
    let mut cursors = Vec::new();
    for frame in stream {
        let frame = frame.expect("stream frame");
        if let Some(c) = frame.cursor {
            cursors.push(c);
        }
    }
    cursors
}

fn active_grants(client: &mut Client) -> u64 {
    client
        .sched_status()
        .expect("sched_status")
        .status
        .get("active_grants")
        .as_u64()
        .expect("active_grants in sched_status")
}

fn assert_strictly_increasing(cursors: &[u64], label: &str) {
    for w in cursors.windows(2) {
        assert!(
            w[1] > w[0],
            "{label}: cursors not strictly increasing: {} then {}",
            w[0],
            w[1]
        );
    }
}

#[test]
fn crash_recovery_over_the_wire() {
    let dir = state_dir();
    std::fs::create_dir_all(&dir).unwrap();

    // ---- first life: admission storm, then SIGKILL ----
    let mut server = spawn_server(&dir);
    let mut c = Client::connect(server.addr).expect("connect");
    let baseline = active_grants(&mut c);
    let user = c.add_user("durable-alice").expect("add_user").user;
    c.quota_set(&QuotaSetRequest {
        user,
        max_vfpgas: Some(16),
        budget_s: None,
        weight: None,
    })
    .expect("quota_set");
    // Six single-region admissions; half release before the crash,
    // half stay live across it.
    let mut live = Vec::new();
    for i in 0..6 {
        let resp = c.alloc_vfpga(user, None, None).expect("alloc_vfpga");
        if i % 2 == 0 {
            live.push((resp.alloc, resp.lease));
        } else {
            assert!(c.release(resp.alloc).expect("release").released);
        }
    }
    assert_eq!(active_grants(&mut c), baseline + live.len() as u64);
    let before = drain_cursors(&mut c);
    assert!(!before.is_empty(), "no public events journaled");
    assert_strictly_increasing(&before, "pre-crash");

    // SIGKILL: no shutdown hook runs; durability comes from the
    // journal alone.
    server.child.kill().expect("kill server");
    server.child.wait().expect("wait server");

    // ---- second life: same state dir ----
    let mut server2 = spawn_server(&dir);
    let mut c2 = Client::connect(server2.addr).expect("reconnect");

    // Every lease held across the crash was re-adopted.
    assert_eq!(
        active_grants(&mut c2),
        baseline + live.len() as u64,
        "re-adopted grant count"
    );
    // Pre-crash capability tokens still validate: each live lease
    // releases exactly once through the recovered placement...
    for (alloc, token) in &live {
        c2.set_lease_token(*alloc, *token);
        assert!(
            c2.release(*alloc).expect("post-restart release").released,
            "{alloc} did not release after recovery"
        );
    }
    // ...and never twice (no double grant survived recovery).
    for (alloc, token) in &live {
        c2.set_lease_token(*alloc, *token);
        assert!(
            c2.release(*alloc).is_err(),
            "{alloc} released twice after recovery"
        );
    }
    assert_eq!(active_grants(&mut c2), baseline, "all ours released");

    // Exactly-once cursor resume: the post-restart replay begins with
    // exactly the pre-crash cursor sequence (no gap, no duplicate, no
    // cursor reuse), then continues past it with the second life's
    // events (re-adoption transitions, the releases above).
    let after = drain_cursors(&mut c2);
    assert_strictly_increasing(&after, "post-restart");
    assert!(
        after.len() > before.len(),
        "restart journaled no new events"
    );
    assert_eq!(
        &after[..before.len()],
        &before[..],
        "replayed cursor prefix diverged across the crash"
    );

    server2.child.kill().expect("kill server2");
    server2.child.wait().expect("wait server2");
    if std::env::var("RC3E_DURABILITY_STATE").is_err() {
        let _ = std::fs::remove_dir_all(&dir);
    }
}
