//! Integration tests for protocol 3: the event-stream middleware
//! surface. Negotiation window `[2, 3]` (v1 retired), ordered
//! server-push subscriptions, job-progress frames that terminate
//! with the exact `job_wait` result, coalesced `job_wait` fan-in,
//! and token-scoped tenant isolation of event delivery.

use std::sync::Arc;
use std::time::Duration;

use rc3e::config::ClusterConfig;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::middleware::api::{
    ErrorCode, Event, HelloRequest, Method, SubscribeRequest,
    SubscriptionFilter, Topic, PROTO_MAX, PROTO_MIN,
};
use rc3e::middleware::{
    read_frame, write_frame, Client, ManagementServer, NodeAgent,
    Response,
};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::NodeId;
use rc3e::util::json::Json;

struct Cloud {
    server: ManagementServer,
    _agents: Vec<NodeAgent>,
    client: Client,
    hv: Arc<Hypervisor>,
}

fn cloud() -> Cloud {
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut agents = Vec::new();
    for n in [NodeId(0), NodeId(1)] {
        let a = NodeAgent::spawn(Arc::clone(&hv), n, None).unwrap();
        server.register_agent(n, a.addr());
        agents.push(a);
    }
    let client = Client::connect(server.addr()).unwrap();
    Cloud {
        server,
        _agents: agents,
        client,
        hv,
    }
}

/// A single-device cloud that also serves RSaaS, for the
/// physical-lease + program_full job path.
fn rsaas_cloud() -> (ManagementServer, Client, Arc<Hypervisor>) {
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let client = Client::connect(server.addr()).unwrap();
    (server, client, hv)
}

// ====================================================== negotiation

#[test]
fn window_is_2_to_4_and_v1_is_rejected() {
    let mut c = cloud();
    assert_eq!(PROTO_MIN, 2);
    assert_eq!(PROTO_MAX, 4);
    let hello = c.client.hello().unwrap();
    assert_eq!(hello.proto_min, 2);
    assert_eq!(hello.proto_max, 4);
    assert_eq!(hello.proto, 4);
    // A v1-window hello does not overlap.
    let err = c
        .client
        .call_v2(
            Method::Hello.name(),
            HelloRequest {
                proto_min: 1,
                proto_max: 1,
            }
            .to_json(),
        )
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::ProtocolMismatch);
    // A proto-less envelope (protocol 1) never reaches dispatch.
    let mut raw =
        std::net::TcpStream::connect(c.server.addr()).unwrap();
    let frame = Json::obj(vec![
        ("method", Json::from("cores")),
        ("params", Json::obj(vec![])),
    ]);
    write_frame(&mut raw, &frame).unwrap();
    let resp =
        Response::from_json(&read_frame(&mut raw).unwrap().unwrap())
            .unwrap();
    let err = resp.into_api_result().unwrap_err();
    assert_eq!(err.code, ErrorCode::ProtocolMismatch);
}

#[test]
fn v2_stamped_envelopes_are_still_served() {
    let c = cloud();
    // A pure-v2 client (proto stamp 2) gets the typed surface.
    let mut raw =
        std::net::TcpStream::connect(c.server.addr()).unwrap();
    let frame = Json::obj(vec![
        ("method", Json::from("cores")),
        ("params", Json::obj(vec![])),
        ("id", Json::from(11u64)),
        ("proto", Json::from(2u64)),
    ]);
    write_frame(&mut raw, &frame).unwrap();
    let resp =
        Response::from_json(&read_frame(&mut raw).unwrap().unwrap())
            .unwrap();
    assert_eq!(resp.id, Some(11));
    let body = resp.into_api_result().unwrap();
    assert!(body.get("cores").as_arr().is_some());
    // ...but `subscribe` is protocol 3 only.
    let frame = Json::obj(vec![
        ("method", Json::from("subscribe")),
        ("params", Json::obj(vec![])),
        ("id", Json::from(12u64)),
        ("proto", Json::from(2u64)),
    ]);
    write_frame(&mut raw, &frame).unwrap();
    let resp =
        Response::from_json(&read_frame(&mut raw).unwrap().unwrap())
            .unwrap();
    let err = resp.into_api_result().unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
}

// ==================================================== subscriptions

#[test]
fn event_seq_is_strictly_increasing() {
    let mut c = cloud();
    let user = c.client.add_user("seq").unwrap().user;
    let addr = c.server.addr();
    let driver = std::thread::spawn(move || {
        let mut d = Client::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        // Three grants → at least three public sched events.
        for _ in 0..3 {
            let lease = d.alloc_vfpga(user, None, None).unwrap();
            d.release(lease.alloc).unwrap();
        }
    });
    let mut watcher = Client::connect(addr).unwrap();
    let frames: Vec<_> = watcher
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::topic(Topic::Sched),
            lease: None,
            max_events: Some(3),
            timeout_s: Some(60.0),
            from_cursor: None,
        })
        .unwrap()
        .map(|r| r.unwrap())
        .collect();
    driver.join().unwrap();
    assert_eq!(frames.len(), 3);
    let mut last = 0;
    for f in &frames {
        assert!(f.seq > last, "seq {} after {}", f.seq, last);
        last = f.seq;
        assert_eq!(f.event.topic(), Topic::Sched);
    }
}

#[test]
fn job_progress_frames_end_with_the_exact_job_wait_result() {
    let (server, mut c, _hv) = rsaas_cloud();
    let user = c.add_user("rs").unwrap().user;
    let lease = c.alloc_physical(user).unwrap();
    let token = c.lease_token(lease.alloc).unwrap();
    let addr = server.addr();
    let (tx, rx) = std::sync::mpsc::channel();
    let submitter = std::thread::spawn(move || {
        let mut d = Client::connect(addr).unwrap();
        d.set_lease_token(lease.alloc, token);
        std::thread::sleep(Duration::from_millis(300));
        let job = d
            .program_full(user, lease.alloc, Some("my_design"))
            .unwrap()
            .job;
        d.set_job_token(job, token);
        // The wire body job_wait returns (retrying through timeouts).
        let body = loop {
            match d.job_wait(job, Some(60.0)) {
                Ok(b) if b.is_terminal() => break b,
                Ok(_) => {}
                Err(e) if e.code == ErrorCode::Timeout => {}
                Err(e) => panic!("job_wait failed: {e}"),
            }
        };
        tx.send((job, body)).unwrap();
    });
    // program_full emits exactly: submitted, build_bitstream,
    // configuring, configured, done.
    let mut watcher = Client::connect(addr).unwrap();
    let frames: Vec<Event> = watcher
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::topic(Topic::Job),
            lease: Some(token),
            max_events: Some(5),
            timeout_s: Some(60.0),
            from_cursor: None,
        })
        .unwrap()
        .map(|r| r.unwrap().event)
        .collect();
    let (job, body) = rx.recv().unwrap();
    submitter.join().unwrap();
    assert_eq!(frames.len(), 5);
    // Mid-job frames first: running, pct < 100, no result (the
    // acceptance assertion — progress is visible *during* the job).
    for f in &frames[..4] {
        match f {
            Event::JobProgress {
                job: j,
                state,
                pct,
                result,
                ..
            } => {
                assert_eq!(*j, job);
                assert_eq!(state, "running");
                assert!(*pct < 100.0);
                assert!(result.is_none());
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    // The terminal frame carries the exact body job_wait returned.
    match &frames[4] {
        Event::JobProgress {
            state,
            pct,
            result,
            ..
        } => {
            assert_eq!(state, "done");
            assert_eq!(*pct, 100.0);
            assert_eq!(result.as_ref().unwrap(), &body.to_json());
        }
        other => panic!("unexpected terminal event {other:?}"),
    }
}

#[test]
fn coalesced_job_wait_wakes_16_wire_clients_at_once() {
    let c = cloud();
    let addr = c.server.addr();
    // A controllable job submitted straight into the server's
    // registry (unowned, so the wire waiters need no token).
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let now_ns = c.hv.clock.now().0;
    let job = Arc::clone(c.server.jobs()).submit(
        "stream",
        now_ns,
        None,
        move |_p| {
            let _ = rx.recv();
            Ok(Json::from(99u64))
        },
    );
    let waiters: Vec<_> = (0..16)
        .map(|_| {
            std::thread::spawn(move || {
                let mut w = Client::connect(addr).unwrap();
                loop {
                    match w.job_wait(job, Some(30.0)) {
                        Ok(b) if b.is_terminal() => return b,
                        Ok(_) => {}
                        Err(e) if e.code == ErrorCode::Timeout => {}
                        Err(e) => panic!("job_wait: {e}"),
                    }
                }
            })
        })
        .collect();
    // Every wire client must be parked on the shared slot before the
    // job completes — the whole point of the coalescing counter.
    while c.server.jobs().waiters(job) < 16 {
        std::thread::sleep(Duration::from_millis(2));
    }
    tx.send(()).unwrap();
    for w in waiters {
        let body = w.join().unwrap();
        assert_eq!(body.state, "done");
        assert_eq!(body.result.unwrap().as_u64(), Some(99));
    }
    // One fanout served all 16 parked callers.
    assert_eq!(
        c.hv.metrics.counter("jobs.wait.coalesced").get(),
        16
    );
}

#[test]
fn subscriptions_never_leak_another_tenants_events() {
    let mut c = cloud();
    let alice = c.client.add_user("alice").unwrap().user;
    let bob = c.client.add_user("bob").unwrap().user;
    let a_lease = c.client.alloc_vfpga(alice, None, None).unwrap();
    let a_token = c.client.lease_token(a_lease.alloc).unwrap();
    let addr = c.server.addr();
    // Bob runs a job on his own lease from another connection.
    let driver = std::thread::spawn(move || {
        let mut d = Client::connect(addr).unwrap();
        let b_lease = d.alloc_vfpga(bob, None, None).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        // The job fails fast without artifacts — frames flow either
        // way (submitted + terminal at minimum).
        let job =
            d.stream(bob, b_lease.alloc, "matmul16", 64).unwrap().job;
        let _ = d.job_wait(job, Some(60.0));
        d.release(b_lease.alloc).unwrap();
    });
    // Alice subscribes to the job topic with *her* token: Bob's job
    // frames are scoped to his owner token and must never arrive.
    let mut watcher = Client::connect(addr).unwrap();
    let frames: Vec<Event> = watcher
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::topic(Topic::Job),
            lease: Some(a_token),
            max_events: None,
            timeout_s: Some(3.0),
            from_cursor: None,
        })
        .unwrap()
        .map(|r| r.unwrap().event)
        .collect();
    driver.join().unwrap();
    assert!(
        frames.is_empty(),
        "leaked another tenant's events: {frames:?}"
    );
    // A subscription without any token sees no token-scoped job
    // frames either (public topics only).
    let mut anon = Client::connect(addr).unwrap();
    let driver = std::thread::spawn(move || {
        let mut d = Client::connect(addr).unwrap();
        let lease = d.alloc_vfpga(bob, None, None).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let job =
            d.stream(bob, lease.alloc, "matmul16", 64).unwrap().job;
        let _ = d.job_wait(job, Some(60.0));
        d.release(lease.alloc).unwrap();
    });
    let frames: Vec<Event> = anon
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::topic(Topic::Job),
            lease: None,
            max_events: None,
            timeout_s: Some(3.0),
            from_cursor: None,
        })
        .unwrap()
        .map(|r| r.unwrap().event)
        .collect();
    driver.join().unwrap();
    assert!(frames.is_empty(), "{frames:?}");
    c.client.release(a_lease.alloc).unwrap();
}

#[test]
fn placement_events_reach_the_moved_tenant() {
    let mut c = cloud();
    let user = c.client.add_user("mover").unwrap().user;
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    let token = c.client.lease_token(lease.alloc).unwrap();
    c.client
        .program_core(user, lease.alloc, "matmul16")
        .unwrap();
    let addr = c.server.addr();
    let driver = std::thread::spawn(move || {
        let mut d = Client::connect(addr).unwrap();
        d.set_lease_token(lease.alloc, token);
        std::thread::sleep(Duration::from_millis(300));
        d.migrate(user, lease.alloc).unwrap()
    });
    let mut watcher = Client::connect(addr).unwrap();
    let frames: Vec<Event> = watcher
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::topic(Topic::Placement),
            lease: Some(token),
            max_events: Some(1),
            timeout_s: Some(30.0),
            from_cursor: None,
        })
        .unwrap()
        .map(|r| r.unwrap().event)
        .collect();
    let mig = driver.join().unwrap();
    assert_eq!(frames.len(), 1);
    match &frames[0] {
        Event::LeasePlacementChanged {
            alloc,
            vfpga,
            migrations,
            ..
        } => {
            assert_eq!(*alloc, lease.alloc);
            assert_eq!(*vfpga, mig.to);
            assert_eq!(*migrations, 1);
        }
        other => panic!("unexpected event {other:?}"),
    }
}

#[test]
fn region_transitions_stream_to_operators() {
    let mut c = cloud();
    let user = c.client.add_user("ops").unwrap().user;
    let addr = c.server.addr();
    let driver = std::thread::spawn(move || {
        let mut d = Client::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let lease = d.alloc_vfpga(user, None, None).unwrap();
        d.program_core(user, lease.alloc, "matmul16").unwrap();
        d.release(lease.alloc).unwrap();
        lease.fpga
    });
    // Token-less operator subscription: region topic is public.
    let mut watcher = Client::connect(addr).unwrap();
    let frames: Vec<Event> = watcher
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::topic(Topic::Region),
            lease: None,
            // alloc → PR start → PR done → release = 4 transitions.
            max_events: Some(4),
            timeout_s: Some(30.0),
            from_cursor: None,
        })
        .unwrap()
        .map(|r| r.unwrap().event)
        .collect();
    let fpga = driver.join().unwrap();
    assert_eq!(frames.len(), 4);
    let edges: Vec<(String, String)> = frames
        .iter()
        .map(|e| match e {
            Event::RegionTransition { fpga: f, from, to, .. } => {
                assert_eq!(*f, fpga);
                (from.clone(), to.clone())
            }
            other => panic!("unexpected event {other:?}"),
        })
        .collect();
    assert_eq!(
        edges,
        vec![
            ("free".to_string(), "reserved".to_string()),
            ("reserved".to_string(), "programming".to_string()),
            ("programming".to_string(), "active".to_string()),
            ("active".to_string(), "free".to_string()),
        ]
    );
    // The same history is queryable after the fact over the
    // lifecycle_log RPC (satellite: the PR 4 transition log RPC).
    let log = watcher.lifecycle_log(fpga, None).unwrap();
    let logged: Vec<(String, String)> = log
        .records
        .iter()
        .map(|r| (r.from.clone(), r.to.clone()))
        .collect();
    assert_eq!(logged, edges);
}
