//! Property-based tests over the coordinator's invariants, using the
//! in-tree property framework (`rc3e::testing::prop`).
//!
//! Invariants:
//! * allocation: a vFPGA never has two owners; free + used == total;
//!   release always restores capacity; RSaaS exclusivity holds under
//!   arbitrary interleavings;
//! * placement: consolidate-first never touches a second device while
//!   the first has room; both policies are deterministic;
//! * JSON: parse(serialize(x)) == x for arbitrary values;
//! * link arbiter: per-stream fair shares sum to ≤ the cap; byte
//!   accounting is conserved;
//! * device DB: save/load is lossless under arbitrary operation
//!   sequences.

use std::sync::Arc;

use rc3e::config::ServiceModel;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::testing::prop::{forall, Gen};
use rc3e::util::clock::VirtualClock;
use rc3e::util::json::Json;
use rc3e::util::rng::Rng;

/// A random sequence of cloud operations.
#[derive(Debug, Clone)]
enum Op {
    Alloc,
    Release(usize),  // index into live allocations (mod len)
    AllocPhysical,
}

fn ops_gen<'a>() -> Gen<'a, Vec<Op>> {
    Gen::new(|rng: &mut Rng, size| {
        let len = rng.next_below(size as u64 * 2 + 1) as usize;
        (0..len)
            .map(|_| match rng.next_below(4) {
                0 | 1 => Op::Alloc,
                2 => Op::Release(rng.next_below(16) as usize),
                _ => Op::AllocPhysical,
            })
            .collect()
    })
}

#[test]
fn prop_allocation_invariants_under_random_interleavings() {
    let gen = ops_gen();
    forall(0xA110C, 60, &gen, |ops| {
        let hv = Hypervisor::boot(
            &rc3e::config::ClusterConfig::paper_testbed(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .map_err(|e| e.to_string())?;
        let user = hv.add_user("prop");
        let mut live: Vec<rc3e::util::ids::AllocationId> = Vec::new();
        for op in ops {
            match op {
                Op::Alloc => {
                    if let Ok((alloc, vfpga, _, _)) =
                        hv.alloc_vfpga(user, ServiceModel::RAaaS)
                    {
                        // No double ownership.
                        let db = hv.db.lock().unwrap();
                        let owner = db
                            .owner_of(vfpga)
                            .ok_or("allocated vfpga has no owner")?;
                        if owner.id != alloc {
                            return Err(format!(
                                "{vfpga} owned by {} not {alloc}",
                                owner.id
                            ));
                        }
                        drop(db);
                        live.push(alloc);
                    }
                }
                Op::Release(i) => {
                    if !live.is_empty() {
                        let idx = i % live.len();
                        let alloc = live.swap_remove(idx);
                        hv.release(alloc).map_err(|e| e.to_string())?;
                    }
                }
                Op::AllocPhysical => {
                    // paper_testbed has no RSaaS devices: must always
                    // fail, never corrupt state.
                    if hv.alloc_physical(user, None).is_ok() {
                        return Err("RSaaS alloc on non-RSaaS cloud".into());
                    }
                }
            }
            // Global capacity invariant after every step.
            let db = hv.db.lock().unwrap();
            let mut free = 0;
            let mut used = 0;
            for f in hv.device_ids() {
                free += db.free_regions(f).len();
                used += db.used_regions(f);
            }
            if free + used != 16 {
                return Err(format!("free {free} + used {used} != 16"));
            }
            if used != live.len() {
                return Err(format!(
                    "db used {used} != live leases {}",
                    live.len()
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_consolidate_first_packs_devices() {
    let gen = Gen::new(|rng: &mut Rng, _| rng.range(1, 16));
    forall(0xC0450, 40, &gen, |&n| {
        let hv = Hypervisor::boot(
            &rc3e::config::ClusterConfig::paper_testbed(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .map_err(|e| e.to_string())?;
        let user = hv.add_user("prop");
        let mut devices_in_order = Vec::new();
        for _ in 0..n {
            let (_, _, fpga, _) = hv
                .alloc_vfpga(user, ServiceModel::RAaaS)
                .map_err(|e| e.to_string())?;
            devices_in_order.push(fpga);
        }
        // A new device may only appear after the previous is full (4).
        let mut counts: std::collections::BTreeMap<_, usize> =
            Default::default();
        let mut seen_order = Vec::new();
        for f in &devices_in_order {
            if !seen_order.contains(f) {
                // All previously seen devices must be full.
                for prev in &seen_order {
                    if counts[prev] < 4 {
                        return Err(format!(
                            "opened {f} while {prev} had {} used",
                            counts[prev]
                        ));
                    }
                }
                seen_order.push(*f);
            }
            *counts.entry(*f).or_default() += 1;
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_json_roundtrip() {
    // Generator for arbitrary JSON trees.
    fn json_gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) }
        {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => {
                // Round-trippable f64s: halves.
                Json::Num((rng.next_below(2_000_001) as f64 - 1e6) / 2.0)
            }
            3 => {
                let len = rng.next_below(12);
                Json::Str(
                    (0..len)
                        .map(|_| {
                            *rng.choose(&[
                                'a', 'ß', '"', '\\', '\n', '😀', ' ', 'z',
                            ])
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.next_below(5))
                    .map(|_| json_gen(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.next_below(5))
                    .map(|i| {
                        (format!("k{i}"), json_gen(rng, depth - 1))
                    })
                    .collect(),
            ),
        }
    }
    let gen = Gen::new(|rng: &mut Rng, size| json_gen(rng, size.min(4)));
    forall(0x15011, 300, &gen, |v| {
        let compact = Json::parse(&v.to_string())
            .map_err(|e| format!("compact: {e}"))?;
        if &compact != v {
            return Err(format!("compact mismatch: {v} vs {compact}"));
        }
        let pretty = Json::parse(&v.to_pretty())
            .map_err(|e| format!("pretty: {e}"))?;
        if &pretty != v {
            return Err("pretty mismatch".into());
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_arbiter_conserves_bytes_and_caps_rate() {
    let gen = Gen::new(|rng: &mut Rng, size| {
        let streams = rng.range(1, 8) as usize;
        let chunks = rng.range(1, size as u64 * 4 + 1) as usize;
        (streams, chunks)
    });
    forall(0xBA2D, 60, &gen, |&(streams, chunks)| {
        let clock = VirtualClock::new();
        let arb = rc3e::pcie::BandwidthArbiter::new(
            Arc::clone(&clock),
            800.0,
        );
        let chunk = 256 * 1024u64;
        let mut handles: Vec<_> =
            (0..streams).map(|_| arb.open_stream()).collect();
        for _ in 0..chunks {
            for h in &mut handles {
                h.transfer(chunk);
            }
        }
        let expect = chunk * chunks as u64 * streams as u64;
        if arb.bytes_total() as u64 != expect {
            return Err(format!(
                "bytes {} != {expect}",
                arb.bytes_total()
            ));
        }
        // Aggregate rate within the cap (+1% chunk-boundary slack).
        let secs = clock.now().as_secs_f64();
        let agg = expect as f64 / 1e6 / secs;
        if agg > 808.0 {
            return Err(format!("aggregate {agg:.1} MB/s beats the cap"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_device_db_save_load_lossless() {
    let gen = ops_gen();
    forall(0xD6DB, 40, &gen, |ops| {
        let hv = Hypervisor::boot(
            &rc3e::config::ClusterConfig::paper_testbed(),
            VirtualClock::new(),
            PlacementPolicy::RoundRobin,
        )
        .map_err(|e| e.to_string())?;
        let user = hv.add_user("prop");
        let mut live = Vec::new();
        for op in ops {
            match op {
                Op::Alloc => {
                    if let Ok((a, _, _, _)) =
                        hv.alloc_vfpga(user, ServiceModel::RAaaS)
                    {
                        live.push(a);
                    }
                }
                Op::Release(i) if !live.is_empty() => {
                    let idx = i % live.len();
                    let a = live.swap_remove(idx);
                    hv.release(a).map_err(|e| e.to_string())?;
                }
                _ => {}
            }
        }
        let db = hv.db.lock().unwrap();
        let json = db.to_json();
        let back = rc3e::hypervisor::DeviceDb::from_json(&json)
            .map_err(|e| e.to_string())?;
        if back.to_json() != json {
            return Err("db json not stable across reload".into());
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_placement_is_deterministic() {
    let gen = Gen::new(|rng: &mut Rng, _| rng.range(1, 16));
    forall(0xDE7E, 25, &gen, |&n| {
        let run = || -> Vec<String> {
            let hv = Hypervisor::boot(
                &rc3e::config::ClusterConfig::paper_testbed(),
                VirtualClock::new(),
                PlacementPolicy::ConsolidateFirst,
            )
            .unwrap();
            let user = hv.add_user("prop");
            (0..n)
                .map(|_| {
                    let (_, v, _, _) =
                        hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
                    v.to_string()
                })
                .collect()
        };
        if run() != run() {
            return Err("same inputs, different placements".into());
        }
        Ok(())
    })
    .unwrap();
}
