//! Failure-injection integration tests: the error paths a healthy
//! simulation never takes.
//!
//! * node agent dying mid-RPC (connection drop) and recovering;
//! * corrupted / tampered / oversized bitfiles at every entry point;
//! * capacity exhaustion and double-release;
//! * streaming against a missing artifact;
//! * FIFO timeout under a stalled producer.

use std::sync::Arc;

use rc3e::bitstream::BitstreamBuilder;
use rc3e::config::ServiceModel;
use rc3e::fpga::Resources;
use rc3e::hypervisor::{Hypervisor, HypervisorError, PlacementPolicy};
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::testing::{FailPlan, FailPoint};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::{FpgaId, NodeId};

fn hv() -> Arc<Hypervisor> {
    Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap())
}

#[test]
fn agent_crash_mid_request_then_recovery() {
    let hv = hv();
    let plan = FailPlan::new();
    plan.arm("agent.drop_conn", FailPoint::OnHit(2));
    let agent =
        NodeAgent::spawn(Arc::clone(&hv), NodeId(0), Some(plan.clone()))
            .unwrap();
    let mut client = Client::connect(agent.addr()).unwrap();
    // First call fine.
    client.agent_hello().unwrap();
    // Second call: the agent "crashes" (drops the connection).
    let err = client.agent_hello().unwrap_err();
    assert!(err.message.starts_with("io:"), "{err}");
    // A fresh connection works — the node is back.
    let mut c2 = Client::connect(agent.addr()).unwrap();
    c2.agent_hello().unwrap();
    assert_eq!(plan.hits("agent.drop_conn"), 3);
}

#[test]
fn management_survives_dead_agent_registration() {
    let hv = hv();
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    // Register an address nobody listens on.
    let dead: std::net::SocketAddr = "127.0.0.1:1".parse().unwrap();
    server.register_agent(NodeId(0), dead);
    let mut client = Client::connect(server.addr()).unwrap();
    // Status of a node-0 device fails cleanly (routed to the dead
    // agent), but the server connection survives...
    let err = client.status(FpgaId(0)).unwrap_err();
    assert!(err.message.contains("connect"), "{err}");
    // ...and node-1 devices (no agent registered) still work.
    let st = client.status(FpgaId(2)).unwrap();
    assert_eq!(st.regions_total, 4);
}

#[test]
fn corrupted_bitfile_rejected_at_every_surface() {
    let hv = hv();
    let user = hv.add_user("evil");
    let (alloc, _, fpga, _) =
        hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
    let part = hv.device(fpga).unwrap().fpga.lock().unwrap().board.part;
    let mut bs = BitstreamBuilder::partial(part, "trojan")
        .resources(Resources::new(100, 100, 1, 1))
        .frames(rc3e::hls::flow::region_window(0, 1))
        .build();
    bs.payload[7] ^= 0x01; // bit-flip in transit
    match hv.program_vfpga(alloc, user, &bs) {
        Err(HypervisorError::Sanity(
            rc3e::bitstream::SanityError::BadCrc,
        )) => {}
        other => panic!("expected BadCrc, got {other:?}"),
    }
    // Region stays unconfigured; lease still usable with a good file.
    let good = BitstreamBuilder::partial(part, "good")
        .resources(Resources::new(100, 100, 1, 1))
        .frames(rc3e::hls::flow::region_window(
            hv.device(fpga).unwrap().slot_of
                [&hv.check_vfpga_lease(alloc, user).unwrap()],
            1,
        ))
        .build();
    hv.program_vfpga(alloc, user, &good).unwrap();
}

#[test]
fn frame_escape_attack_is_contained() {
    let hv = hv();
    let alice = hv.add_user("alice");
    let mallory = hv.add_user("mallory");
    // Alice has a running design in some region.
    let (a_alloc, a_vfpga, fpga, _) =
        hv.alloc_vfpga(alice, ServiceModel::RAaaS).unwrap();
    let part = hv.device(fpga).unwrap().fpga.lock().unwrap().board.part;
    let a_slot = hv.device(fpga).unwrap().slot_of[&a_vfpga];
    let good = BitstreamBuilder::partial(part, "alice_core")
        .resources(Resources::new(100, 100, 1, 1))
        .frames(rc3e::hls::flow::region_window(a_slot, 1))
        .build();
    hv.program_vfpga(a_alloc, alice, &good).unwrap();
    // Mallory leases the neighboring region and submits a bitfile
    // whose frames overlap ALICE's window.
    let (m_alloc, _, m_fpga, _) =
        hv.alloc_vfpga(mallory, ServiceModel::RAaaS).unwrap();
    assert_eq!(fpga, m_fpga, "consolidation co-locates them");
    let attack = BitstreamBuilder::partial(part, "overwrite_alice")
        .resources(Resources::new(100, 100, 1, 1))
        .frames(rc3e::hls::flow::region_window(a_slot, 1))
        .build();
    match hv.program_vfpga(m_alloc, mallory, &attack) {
        Err(HypervisorError::Sanity(
            rc3e::bitstream::SanityError::FrameEscape { .. },
        )) => {}
        other => panic!("expected FrameEscape, got {other:?}"),
    }
    // Alice's design is untouched.
    let dev = hv.device(fpga).unwrap();
    assert!(dev
        .fpga
        .lock()
        .unwrap()
        .region(a_vfpga)
        .unwrap()
        .is_configured());
}

#[test]
fn capacity_exhaustion_and_recovery() {
    let hv = hv();
    let user = hv.add_user("greedy");
    let mut leases = Vec::new();
    for _ in 0..16 {
        leases.push(hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap().0);
    }
    assert!(matches!(
        hv.alloc_vfpga(user, ServiceModel::RAaaS),
        Err(HypervisorError::NoCapacity)
    ));
    // Releasing one restores exactly one slot.
    hv.release(leases.pop().unwrap()).unwrap();
    hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
    assert!(matches!(
        hv.alloc_vfpga(user, ServiceModel::RAaaS),
        Err(HypervisorError::NoCapacity)
    ));
}

#[test]
fn double_release_is_an_error_not_a_panic() {
    let hv = hv();
    let user = hv.add_user("u");
    let (alloc, _, _, _) =
        hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
    hv.release(alloc).unwrap();
    assert!(matches!(hv.release(alloc), Err(HypervisorError::Db(_))));
}

#[test]
fn stream_against_missing_artifact_fails_cleanly() {
    if !rc3e::testing::artifacts_available(
        "failure_injection::stream_against_missing_artifact_fails_cleanly",
    ) {
        return;
    }
    let hv = hv();
    let fpga = hv.device_ids()[0];
    let link = Arc::clone(&hv.device(fpga).unwrap().link);
    let runner = rc3e::rc2f::StreamRunner::new(
        Arc::clone(&hv.clock),
        link,
    );
    let cfg = rc3e::rc2f::StreamConfig {
        artifact: "matmul99_b1".to_string(),
        ..rc3e::rc2f::StreamConfig::matmul16(256)
    };
    let err = runner.run(&cfg).unwrap_err();
    assert!(err.contains("matmul99"), "{err}");
}

#[test]
fn fifo_timeout_surfaces_stalled_producer() {
    let fifo = rc3e::fifo::AsyncFifo::new("stall", 1024);
    let err = fifo
        .pop_timeout(std::time::Duration::from_millis(10))
        .unwrap_err();
    assert!(matches!(err, rc3e::fifo::FifoError::Timeout(_)));
}

#[test]
fn oversized_rpc_frame_rejected() {
    let hv = hv();
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    // Hand-roll a frame that claims to be huge.
    use std::io::Write;
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    stream
        .write_all(&(u32::MAX).to_le_bytes())
        .unwrap();
    // Server closes the connection; a read yields EOF quickly.
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(2)))
        .unwrap();
    let mut buf = [0u8; 4];
    use std::io::Read;
    let n = stream.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server should drop oversized frames");
    // And the server still serves new connections.
    let mut client = Client::connect(server.addr()).unwrap();
    client.hello().unwrap();
}
