//! Integration tests for the typed, versioned middleware API v2:
//! protocol negotiation, structured error codes, async job handles,
//! and a client↔server round trip through every typed method.

use std::sync::Arc;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::hypervisor::{Hypervisor, HypervisorError, PlacementPolicy};
use rc3e::middleware::api::{
    ApiError, ErrorCode, HelloRequest, Method, QuotaSetRequest,
    ReserveRequest, StreamOutcomeBody, WorkloadRequest, PROTO_MAX,
    PROTO_MIN,
};
use rc3e::middleware::{
    read_frame, write_frame, Client, ManagementServer, NodeAgent,
    Response,
};
use rc3e::sched::{RequestClass, SchedError};
use rc3e::util::clock::{VirtualClock, VirtualTime};
use rc3e::util::ids::{AllocationId, FpgaId, JobId, NodeId};
use rc3e::util::json::Json;

struct Cloud {
    server: ManagementServer,
    agents: Vec<NodeAgent>,
    client: Client,
    hv: Arc<Hypervisor>,
}

fn cloud() -> Cloud {
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut agents = Vec::new();
    for n in [NodeId(0), NodeId(1)] {
        let a = NodeAgent::spawn(Arc::clone(&hv), n, None).unwrap();
        server.register_agent(n, a.addr());
        agents.push(a);
    }
    let client = Client::connect(server.addr()).unwrap();
    Cloud {
        server,
        agents,
        client,
        hv,
    }
}

/// A single-device cloud that also serves RSaaS (the paper testbed
/// does not), for the physical-lease + program_full job path.
fn rsaas_cloud() -> (ManagementServer, Client, Arc<Hypervisor>) {
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let client = Client::connect(server.addr()).unwrap();
    (server, client, hv)
}

// ====================================================== negotiation

#[test]
fn hello_negotiates_protocol_window() {
    let mut c = cloud();
    let hello = c.client.hello().unwrap();
    assert_eq!(hello.version, rc3e::VERSION);
    assert_eq!(hello.service, "rc3e-management");
    assert_eq!(hello.proto_min, PROTO_MIN);
    assert_eq!(hello.proto_max, PROTO_MAX);
    assert_eq!(hello.proto, PROTO_MAX);
    // connect_negotiated wraps the same handshake.
    let (_c2, h2) =
        Client::connect_negotiated(c.server.addr()).unwrap();
    assert_eq!(h2.proto, PROTO_MAX);
}

#[test]
fn version_mismatch_is_rejected_with_code() {
    let mut c = cloud();
    // A future-only client window is rejected at hello...
    let future = HelloRequest {
        proto_min: PROTO_MAX + 1,
        proto_max: PROTO_MAX + 5,
    };
    let err = c
        .client
        .call_v2(Method::Hello.name(), future.to_json())
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::ProtocolMismatch);
    assert!(!err.retryable);
    // ...and a request stamped with an unsupported envelope protocol
    // is rejected before dispatch, whatever the method.
    let mut stream =
        std::net::TcpStream::connect(c.server.addr()).unwrap();
    let raw = Json::obj(vec![
        ("method", Json::from("hello")),
        ("params", Json::obj(vec![])),
        ("id", Json::from(1u64)),
        ("proto", Json::from(99u64)),
    ]);
    write_frame(&mut stream, &raw).unwrap();
    let frame = read_frame(&mut stream).unwrap().unwrap();
    let resp = Response::from_json(&frame).unwrap();
    assert_eq!(resp.id, Some(1));
    let err = resp.into_api_result().unwrap_err();
    assert_eq!(err.code, ErrorCode::ProtocolMismatch);
}

// ====================================================== error codes

#[test]
fn every_error_code_roundtrips_the_wire() {
    for code in ErrorCode::ALL {
        let e = ApiError::new(code, format!("synthetic {}", code.name()));
        let rt = ApiError::from_json(&e.to_json()).unwrap();
        assert_eq!(rt.code, code);
        assert_eq!(rt.retryable, code.retryable());
        // The name is stable and parseable.
        assert_eq!(ErrorCode::parse(code.name()), Some(code));
    }
}

#[test]
fn every_sched_and_hypervisor_error_maps_to_a_code() {
    use rc3e::util::ids::ReservationId;
    let sched_cases: Vec<(SchedError, ErrorCode)> = vec![
        (SchedError::NoCapacity, ErrorCode::NoCapacity),
        (SchedError::QuotaBudget("b".into()), ErrorCode::QuotaBudget),
        (
            SchedError::QuotaConcurrency("c".into()),
            ErrorCode::QuotaExceeded,
        ),
        (SchedError::Hypervisor("h".into()), ErrorCode::Internal),
        (
            SchedError::UnknownGrant(AllocationId(7)),
            ErrorCode::BadLease,
        ),
        (SchedError::UnknownLease, ErrorCode::BadToken),
        (
            SchedError::Unsatisfiable("impossible".into()),
            ErrorCode::BadRequest,
        ),
        (SchedError::Cancelled, ErrorCode::Cancelled),
        (
            SchedError::UnknownReservation(ReservationId(1)),
            ErrorCode::UnknownReservation,
        ),
    ];
    for (e, expect) in sched_cases {
        assert_eq!(ApiError::from(&e).code, expect, "{e}");
    }
    let hv_cases: Vec<(HypervisorError, ErrorCode)> = vec![
        (HypervisorError::NoCapacity, ErrorCode::NoCapacity),
        (HypervisorError::Db("d".into()), ErrorCode::Internal),
        (HypervisorError::Device("x".into()), ErrorCode::DeviceFault),
        (
            HypervisorError::Sanity(
                rc3e::bitstream::SanityError::BadCrc,
            ),
            ErrorCode::SanityRejected,
        ),
        (
            HypervisorError::BadAllocation(AllocationId(3)),
            ErrorCode::BadLease,
        ),
        (
            HypervisorError::WrongKind(AllocationId(3)),
            ErrorCode::BadLease,
        ),
        (
            HypervisorError::UnknownDevice(FpgaId(9)),
            ErrorCode::UnknownDevice,
        ),
        (
            HypervisorError::UnknownService("s".into()),
            ErrorCode::UnknownService,
        ),
        (HypervisorError::Sched("s".into()), ErrorCode::Internal),
    ];
    for (e, expect) in hv_cases {
        assert_eq!(ApiError::from(&e).code, expect, "{e}");
    }
}

#[test]
fn wire_errors_carry_machine_readable_codes() {
    let mut c = cloud();
    let user = c.client.add_user("coder").unwrap().user;

    // Bad request: missing field.
    let err = c
        .client
        .call_v2(Method::Status.name(), Json::obj(vec![]))
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);

    // Unknown method.
    let err = c
        .client
        .call_v2("reboot_world", Json::obj(vec![]))
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownMethod);

    // Unknown device.
    let err = c.client.status(FpgaId(99)).unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownDevice);

    // Bad lease: release of a never-granted allocation.
    let err = c.client.release(AllocationId(999)).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadLease);

    // Unknown core.
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    let err = c
        .client
        .program_core(user, lease.alloc, "warpcore")
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownCore);

    // Unknown service (BAaaS job fails with the typed code).
    let job = c.client.invoke_service(user, "no-such", 16).unwrap().job;
    let err = c.client.job_wait_done(job).unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownService);

    // Unknown reservation.
    let err = c
        .client
        .cancel_reservation(rc3e::util::ids::ReservationId(42))
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownReservation);

    // Unknown job.
    let err = c.client.job_status(JobId(4242)).unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownJob);
    c.client.release(lease.alloc).unwrap();
}

#[test]
fn quota_and_capacity_errors_are_actionable() {
    let mut c = cloud();
    let user = c.client.add_user("bounded").unwrap().user;
    c.client
        .quota_set(&QuotaSetRequest {
            user,
            max_vfpgas: Some(1),
            budget_s: None,
            weight: None,
        })
        .unwrap();
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    // Concurrency quota: retryable, with a backoff hint.
    let err = c.client.alloc_vfpga(user, None, None).unwrap_err();
    assert_eq!(err.code, ErrorCode::QuotaExceeded);
    assert!(err.retryable);
    assert!(err.retry_after_s.is_some());
    c.client.release(lease.alloc).unwrap();

    // Budget exhaustion: terminal, not retryable.
    c.client
        .quota_set(&QuotaSetRequest {
            user,
            max_vfpgas: Some(0),
            budget_s: Some(1.0),
            weight: None,
        })
        .unwrap();
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    c.hv.clock.advance(VirtualTime::from_secs_f64(10.0));
    c.client.release(lease.alloc).unwrap();
    let err = c.client.alloc_vfpga(user, None, None).unwrap_err();
    assert_eq!(err.code, ErrorCode::QuotaBudget);
    assert!(!err.retryable);

    // NoCapacity: another tenant walled off by a full reservation.
    let holder = c.client.add_user("holder").unwrap().user;
    let other = c.client.add_user("other").unwrap().user;
    let r = c
        .client
        .reserve(&ReserveRequest {
            user: holder,
            regions: 16,
            model: None,
            start_s: None,
            duration_s: Some(10_000.0),
        })
        .unwrap();
    let err = c.client.alloc_vfpga(other, None, None).unwrap_err();
    assert_eq!(err.code, ErrorCode::NoCapacity);
    assert!(err.retryable);
    c.client.cancel_reservation(r.reservation).unwrap();
    assert!(c.client.alloc_vfpga(other, None, None).is_ok());
}

// ============================================================= jobs

#[test]
fn job_lifecycle_submit_status_wait_cancel() {
    let (_s, mut c, _hv) = rsaas_cloud();
    let user = c.add_user("rs").unwrap().user;
    let lease = c.alloc_physical(user).unwrap();

    // Submit: the handle comes back immediately.
    let job = c
        .program_full(user, lease.alloc, Some("my_design"))
        .unwrap()
        .job;

    // Status: running or already done, never an error.
    let body = c.job_status(job).unwrap();
    assert!(matches!(body.state.as_str(), "running" | "done"));
    assert_eq!(body.method, "program_full");

    // Wait reproduces the old synchronous result.
    let result = c.job_wait_done(job).unwrap();
    let resp =
        rc3e::middleware::api::ProgramFullResponse::from_json(&result)
            .unwrap();
    assert_eq!(resp.programmed, "my_design");
    // Full config via RC3E ≈ 29.4 virtual seconds (Table I).
    assert!(resp.config_s > 20.0, "{}", resp.config_s);

    // Cancel after completion: terminal state is immutable.
    let cancelled = c.job_cancel(job).unwrap();
    assert_eq!(cancelled.state, "done");

    // The sync convenience wrapper is the same flow in one call.
    let resp2 = c
        .program_full_sync(user, lease.alloc, None)
        .unwrap();
    assert_eq!(resp2.programmed, "user_design");
    c.release(lease.alloc).unwrap();
}

#[test]
fn stream_jobs_reproduce_synchronous_outcomes() {
    let mut c = cloud();
    let user = c.client.add_user("streamer").unwrap().user;
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();
    c.client
        .program_core(user, lease.alloc, "matmul16")
        .unwrap();
    // The job handle comes back regardless of artifact availability;
    // the job then terminates either way.
    let job = c
        .client
        .stream(user, lease.alloc, "matmul16", 256)
        .unwrap()
        .job;
    let body = c.client.job_wait(job, Some(60.0)).unwrap();
    assert!(body.is_terminal(), "{:?}", body.state);
    if rc3e::testing::artifacts_available("api_v2::stream_jobs") {
        let out = StreamOutcomeBody::from_json(
            &body.into_done().unwrap(),
        )
        .unwrap();
        assert_eq!(out.validation_failures, 0);
        assert!(out.virtual_mbps > 400.0);
        // stream_sync ≡ submit + wait.
        let out2 = c
            .client
            .stream_sync(user, lease.alloc, "matmul16", 256)
            .unwrap();
        assert_eq!(out2.validation_failures, 0);
    }
    c.client.release(lease.alloc).unwrap();
}

#[test]
fn invoke_service_runs_as_job() {
    let mut c = cloud();
    // Provider registers a service; end users see only its name.
    let synth = rc3e::hls::Synthesizer::new();
    let report =
        synth.synthesize(&rc3e::hls::CoreSpec::matmul(16, "xc7vx485t"));
    c.hv.register_service(
        "linalg",
        rc3e::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "matmul16",
        )
        .resources(report.total_for(1))
        .frames(rc3e::hls::flow::region_window(0, 1))
        .artifact("matmul16_b256")
        .build(),
    );
    let user = c.client.add_user("enduser").unwrap().user;
    assert!(c
        .client
        .services()
        .unwrap()
        .services
        .contains(&"linalg".to_string()));
    let job = c.client.invoke_service(user, "linalg", 64).unwrap().job;
    let body = c.client.job_wait(job, Some(60.0)).unwrap();
    assert!(body.is_terminal());
    if rc3e::testing::artifacts_available("api_v2::invoke_service") {
        let out =
            StreamOutcomeBody::from_json(&body.into_done().unwrap())
                .unwrap();
        assert_eq!(out.validation_failures, 0);
    }
}

// ================================== typed round trips, full surface

#[test]
fn typed_roundtrip_across_the_surface() {
    let mut c = cloud();

    // add_user / alloc_vfpga with explicit model+class.
    let user = c.client.add_user("alice").unwrap().user;
    let lease = c
        .client
        .alloc_vfpga(
            user,
            Some(ServiceModel::RAaaS),
            Some(RequestClass::Interactive),
        )
        .unwrap();
    assert_eq!(lease.wait_ms, 0.0);
    // Every alloc now returns the capability token (single-region
    // responses carry a one-member gang list).
    assert!(lease.lease.to_string().starts_with("lt-"));
    assert_eq!(lease.members.len(), 1);
    assert_eq!(lease.members[0].alloc, lease.alloc);

    // status (routed through the node agent).
    let st = c.client.status(lease.fpga).unwrap();
    assert_eq!(st.fpga, lease.fpga);
    assert_eq!(st.regions_total, 4);

    // program_core + migrate.
    let prog = c
        .client
        .program_core(user, lease.alloc, "matmul16")
        .unwrap();
    assert_eq!(prog.programmed, "matmul16");
    assert!(prog.pr_ms > 700.0);
    let mig = c.client.migrate(user, lease.alloc).unwrap();
    assert_ne!(mig.from, mig.to);
    assert!(mig.downtime_ms > 0.0);

    // cores / services.
    let cores = c.client.cores().unwrap();
    assert!(cores.cores.contains(&"matmul16".to_string()));
    let services = c.client.services().unwrap();
    assert!(services.services.is_empty());

    // monitor carries device summaries + scheduler telemetry.
    let mon = c.client.monitor().unwrap();
    assert_eq!(mon.devices.as_arr().unwrap().len(), 4);
    assert_eq!(mon.sched.active_grants, 1);
    assert!(mon.sched.wait.count >= 1);

    // sched_status / quota / usage / reservations.
    let sched = c.client.sched_status().unwrap();
    assert_eq!(sched.status.get("active_grants").as_u64(), Some(1));
    let q = c
        .client
        .quota_set(&QuotaSetRequest {
            user,
            max_vfpgas: Some(4),
            budget_s: None,
            weight: Some(2),
        })
        .unwrap();
    assert_eq!(q.max_vfpgas, 4);
    assert_eq!(q.in_use, 1);
    let q2 = c.client.quota_get(user).unwrap();
    assert_eq!(q2.weight, 2);
    let r = c
        .client
        .reserve(&ReserveRequest {
            user,
            regions: 2,
            model: None,
            start_s: None,
            duration_s: Some(50.0),
        })
        .unwrap();
    c.client.cancel_reservation(r.reservation).unwrap();

    // release + usage report.
    assert!(c.client.release(lease.alloc).unwrap().released);
    let usage = c.client.usage_report().unwrap();
    assert!(usage.table.contains("tenant"));
    assert_eq!(usage.tenants.as_arr().unwrap().len(), 1);

    // energy + db_dump.
    let energy = c.client.energy().unwrap();
    assert!(energy.joules >= 0.0);
    let dump = c.client.db_dump().unwrap();
    let db = rc3e::hypervisor::DeviceDb::from_json(&dump.db).unwrap();
    assert_eq!(db.devices.len(), 4);

    // workload (small synthetic run).
    let report = c
        .client
        .workload(&WorkloadRequest {
            rate: Some(0.5),
            hold_s: Some(5.0),
            sessions: Some(3),
            seed: Some(7),
        })
        .unwrap();
    assert_eq!(report.served + report.rejected, 3);

    // agent methods, typed, straight at an agent.
    let mut ac = Client::connect(c.agents[0].addr()).unwrap();
    let hello = ac.agent_hello().unwrap();
    assert_eq!(hello.node, NodeId(0));
    let ast = ac.agent_status(FpgaId(0)).unwrap();
    assert_eq!(ast.board, "vc707");
}

#[test]
fn protocol_1_is_retired() {
    let mut c = cloud();
    // A proto-less (protocol-1) request is rejected before dispatch,
    // whatever the method — the untyped surface stayed readable for
    // exactly one version behind and was dropped when v3 landed.
    let mut stream =
        std::net::TcpStream::connect(c.server.addr()).unwrap();
    for method in ["cores", "hello", "alloc_vfpga"] {
        let raw = Json::obj(vec![
            ("method", Json::from(method)),
            ("params", Json::obj(vec![])),
        ]);
        write_frame(&mut stream, &raw).unwrap();
        let frame = read_frame(&mut stream).unwrap().unwrap();
        let err = Response::from_json(&frame)
            .unwrap()
            .into_api_result()
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::ProtocolMismatch, "{method}");
    }
    // A v1-window hello is likewise refused...
    let legacy = HelloRequest {
        proto_min: 1,
        proto_max: 1,
    };
    let err = c
        .client
        .call_v2(Method::Hello.name(), legacy.to_json())
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::ProtocolMismatch);
    // ...while the typed surface (an object-shaped catalogue) works.
    let cores2 = c
        .client
        .call_v2(Method::Cores.name(), Json::obj(vec![]))
        .unwrap();
    assert!(cores2.get("cores").as_arr().is_some());
    // The hypervisor stayed consistent underneath.
    assert_eq!(c.hv.device_ids().len(), 4);
}
