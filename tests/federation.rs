//! Cluster-federation chaos test: the full multi-process loop.
//!
//! Spawns the real binaries — one `rc3e serve --federated` management
//! server plus two `rc3e node` daemons over loopback TCP — and drives
//! the whole lifecycle through the management client:
//!
//! * placement: board-constrained admissions land on the node that
//!   owns the board model; unconstrained admissions go to the node
//!   with the most free regions;
//! * cross-node data path: `program` and `stream` proxy to the lease's
//!   home daemon and return the same typed responses as local serving;
//! * failure-driven re-admission: SIGKILLing a node daemon mid-storm
//!   re-admits its leases on the survivor **with the same capability
//!   token**, which keeps validating (release works exactly once);
//! * rejoin: restarting the dead daemon on its state directory
//!   re-adopts its WAL leases, reports them at registration and
//!   releases the ones the cluster re-homed while it was gone;
//! * federated cursors: a single `subscribe` stream observes
//!   node-tagged events from both nodes, with per-node journal
//!   cursors strictly increasing across the failure.
//!
//! Health detection needs ~1 s of wall time (250 ms heartbeats, down
//! after 3 misses), so every wait here polls with a generous deadline.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use rc3e::middleware::api::{
    AllocVfpgaRequest, Event, SubscribeRequest, SubscriptionFilter,
};
use rc3e::middleware::Client;
use rc3e::util::ids::NodeId;

struct Proc {
    child: Child,
    addr: SocketAddr,
}

impl Proc {
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn read_addr(child: &mut Child, what: &str) -> SocketAddr {
    let stdout = child.stdout.take().unwrap();
    let line = BufReader::new(stdout)
        .lines()
        .next()
        .unwrap_or_else(|| panic!("{what} exited before printing"))
        .expect("read child stdout");
    line.trim().parse().expect("child address")
}

fn spawn_mgmt(dir: &Path) -> Proc {
    std::fs::create_dir_all(dir).unwrap();
    let mut child = Command::new(env!("CARGO_BIN_EXE_rc3e"))
        .arg("serve")
        .arg("--federated")
        .arg("--state")
        .arg(dir)
        .args(["--timescale", "0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn rc3e serve --federated");
    let addr = read_addr(&mut child, "management server");
    Proc { child, addr }
}

fn spawn_node(index: usize, mgmt: SocketAddr, dir: &Path) -> Proc {
    std::fs::create_dir_all(dir).unwrap();
    let mut child = Command::new(env!("CARGO_BIN_EXE_rc3e"))
        .arg("node")
        .args(["--node-index", &index.to_string()])
        .args(["--mgmt", &mgmt.to_string()])
        .arg("--state")
        .arg(dir)
        .args(["--timescale", "0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn rc3e node");
    let addr = read_addr(&mut child, "node daemon");
    Proc { child, addr }
}

fn test_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rc3e-federation-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if cond() {
            return;
        }
        assert!(Instant::now() < deadline, "timed out waiting: {what}");
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// `(state, leases, regions_free)` of one node per `node_list`.
fn node_row(c: &mut Client, node: NodeId) -> Option<(String, u64, u64)> {
    let resp = c.node_list().ok()?;
    resp.nodes
        .iter()
        .find(|n| n.node == node)
        .map(|n| (n.state.clone(), n.leases, n.regions_free))
}

/// Replay every public journaled event from cursor 1 and group the
/// node-tagged ones by origin (a ~1 s live window closes the stream).
fn node_cursors(c: &mut Client) -> BTreeMap<NodeId, Vec<u64>> {
    let stream = c
        .subscribe(&SubscribeRequest {
            filter: SubscriptionFilter::all(),
            lease: None,
            max_events: None,
            timeout_s: Some(1.0),
            from_cursor: Some(1),
        })
        .expect("subscribe");
    let mut by_node: BTreeMap<NodeId, Vec<u64>> = BTreeMap::new();
    for frame in stream {
        let frame = frame.expect("stream frame");
        if let Event::NodeTagged {
            node, node_cursor, ..
        } = frame.event
        {
            by_node.entry(node).or_default().push(node_cursor);
        }
    }
    by_node
}

fn assert_strictly_increasing(cursors: &[u64], label: &str) {
    for w in cursors.windows(2) {
        assert!(
            w[1] > w[0],
            "{label}: node cursors not strictly increasing: \
             {} then {}",
            w[0],
            w[1]
        );
    }
}

#[test]
fn two_node_cluster_serves_cross_node_lifecycle() {
    let root = test_root("lifecycle");
    let mut mgmt = spawn_mgmt(&root.join("mgmt"));
    let mut c = Client::connect(mgmt.addr).expect("connect");
    let mut node0 = spawn_node(0, mgmt.addr, &root.join("node0"));
    let mut node1 = spawn_node(1, mgmt.addr, &root.join("node1"));
    wait_until("both nodes up", || {
        let Ok(resp) = c.node_list() else { return false };
        resp.nodes.iter().filter(|n| n.state == "up").count() == 2
    });

    let user = c.add_user("fed-alice").expect("add_user").user;

    // Board constraints are placement filters: vc707 lives on node-0
    // of the paper testbed, ml605 on node-1.
    let mut req = AllocVfpgaRequest::single(user, None, None);
    req.board = Some("vc707".to_string());
    let a0 = c.alloc_vfpga_with(&req).expect("vc707 alloc");
    assert_eq!(a0.node, NodeId(0), "vc707 must place on node-0");
    let mut req = AllocVfpgaRequest::single(user, None, None);
    req.board = Some("ml605".to_string());
    let a1 = c.alloc_vfpga_with(&req).expect("ml605 alloc");
    assert_eq!(a1.node, NodeId(1), "ml605 must place on node-1");

    // Full data path through the lease's home daemon.
    let prog = c
        .program_core(user, a0.alloc, "matmul16")
        .expect("program_core via federation");
    assert_eq!(prog.programmed, "matmul16");
    let out = c
        .stream_sync(user, a0.alloc, "matmul16", 4096)
        .expect("stream via federation");
    assert_eq!(out.mults, 4096);
    assert!(out.output_bytes > 0);

    assert!(c.release(a0.alloc).expect("release a0").released);
    assert!(c.release(a1.alloc).expect("release a1").released);

    // One subscribe stream covers the whole cluster: node-tagged
    // events from both daemons, per-node cursors strictly increasing.
    let mut by_node = BTreeMap::new();
    wait_until("events forwarded from both nodes", || {
        by_node = node_cursors(&mut c);
        by_node.contains_key(&NodeId(0)) && by_node.contains_key(&NodeId(1))
    });
    for (node, cursors) in &by_node {
        assert_strictly_increasing(cursors, &node.to_string());
    }

    node0.kill();
    node1.kill();
    mgmt.kill();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn killing_a_node_readmits_its_leases_on_the_survivor() {
    let root = test_root("chaos");
    let mut mgmt = spawn_mgmt(&root.join("mgmt"));
    let mut c = Client::connect(mgmt.addr).expect("connect");
    let mut node0 = spawn_node(0, mgmt.addr, &root.join("node0"));
    let mut node1 = spawn_node(1, mgmt.addr, &root.join("node1"));
    wait_until("both nodes up", || {
        let Ok(resp) = c.node_list() else { return false };
        resp.nodes.iter().filter(|n| n.state == "up").count() == 2
    });

    let user = c.add_user("fed-bob").expect("add_user").user;

    // Fill node-0 down to 2 free regions so the placement choice for
    // everything after is forced, not heuristic.
    let mut req = AllocVfpgaRequest::single(user, None, None);
    req.board = Some("vc707".to_string());
    req.regions = Some(6);
    let fill = c.alloc_vfpga_with(&req).expect("gang on node-0");
    assert_eq!(fill.node, NodeId(0));
    assert_eq!(fill.members.len(), 6);
    wait_until("node-0 vitals refreshed", || {
        node_row(&mut c, NodeId(0))
            .is_some_and(|(_, _, free)| free == 2)
    });

    // Unconstrained admission goes to the node with the most free
    // regions — node-1 with all 8.
    let roam = c.alloc_vfpga(user, None, None).expect("alloc");
    assert_eq!(roam.node, NodeId(1), "most-free placement");
    let token = roam.lease;

    // SIGKILL the daemon holding the lease: nothing graceful runs.
    node1.kill();
    wait_until("node-1 marked down", || {
        node_row(&mut c, NodeId(1))
            .is_some_and(|(state, _, _)| state == "down")
    });
    // The orphaned lease re-admits on the survivor, keeping its
    // token: node-0 now homes both leases.
    wait_until("lease re-admitted on node-0", || {
        node_row(&mut c, NodeId(0))
            .is_some_and(|(_, leases, _)| leases == 2)
    });

    // Rejoin: the restarted daemon re-adopts the lease from its WAL,
    // reports it at registration, learns it was re-homed and releases
    // its local copy — no double grant survives.
    let mut node1b = spawn_node(1, mgmt.addr, &root.join("node1"));
    wait_until("node-1 rejoined", || {
        node_row(&mut c, NodeId(1))
            .is_some_and(|(state, _, _)| state == "up")
    });
    wait_until("node-1 reconciled its stale lease", || {
        node_row(&mut c, NodeId(1))
            .is_some_and(|(_, leases, _)| leases == 0)
    });

    // The capability token stayed valid end to end: it releases
    // exactly once, through the re-homed placement.
    c.set_lease_token(roam.alloc, token);
    assert!(
        c.release(roam.alloc).expect("release after failover").released,
        "re-admitted lease did not release"
    );
    c.set_lease_token(roam.alloc, token);
    assert!(
        c.release(roam.alloc).is_err(),
        "re-admitted lease released twice"
    );
    assert!(c.release(fill.alloc).expect("release gang").released);

    // Federated cursor streams survived the failure: both nodes'
    // tagged cursors strictly increase across the kill + rejoin.
    let mut by_node = BTreeMap::new();
    wait_until("events forwarded from both nodes", || {
        by_node = node_cursors(&mut c);
        by_node.contains_key(&NodeId(0)) && by_node.contains_key(&NodeId(1))
    });
    for (node, cursors) in &by_node {
        assert_strictly_increasing(cursors, &node.to_string());
    }

    node0.kill();
    node1b.kill();
    mgmt.kill();
    let _ = std::fs::remove_dir_all(&root);
}
