//! Region-lifecycle invariants: the tentpole guarantees of the
//! quiesce/pin refactor.
//!
//! * property test — arbitrary interleavings of allocate / program /
//!   relocate / release never record an illegal transition in any
//!   device's transition log, and settle into a db-consistent state;
//! * threaded gang-relocation atomicity — relocations racing live
//!   reprogramming either move every member or none, and never race
//!   an in-flight PR (`sched.preempt.raced` stays 0);
//! * preemption storm over streaming BAaaS invocations
//!   (artifacts-gated) — the defense-in-depth retry never fires.

use std::sync::Arc;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::fpga::LifecycleState;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::rc2f::StreamConfig;
use rc3e::sched::{AdmissionRequest, Lease, RequestClass, Scheduler};
use rc3e::service::BaaasService;
use rc3e::testing::prop::{forall, Gen};
use rc3e::testing::{fill_batch_leases, mm16_partial};
use rc3e::util::clock::VirtualClock;

fn sched_on(config: &ClusterConfig) -> Arc<Scheduler> {
    let hv = Arc::new(
        Hypervisor::boot(
            config,
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    Scheduler::new(hv)
}

/// Every record in every device's transition log is a legal edge of
/// the state machine.
fn assert_log_legal(sched: &Scheduler) {
    for fpga in sched.hv().device_ids() {
        let log = sched
            .hv()
            .device(fpga)
            .unwrap()
            .fpga
            .lock()
            .unwrap()
            .transition_log();
        for rec in &log {
            assert!(
                rec.is_legal(),
                "illegal transition recorded on {fpga}: {rec:?}"
            );
        }
    }
}

/// With no operation in flight, every region must be in a quiescent
/// state consistent with the device DB: owned regions are Reserved or
/// Active, free regions are Free — never Programming / Draining /
/// Migrating.
fn assert_settled(sched: &Scheduler) {
    let hv = sched.hv();
    for fpga in hv.device_ids() {
        let owned: Vec<_> = {
            let db = hv.db.lock().unwrap();
            db.device(fpga)
                .map(|d| {
                    d.regions
                        .iter()
                        .filter(|v| db.owner_of(**v).is_some())
                        .copied()
                        .collect()
                })
                .unwrap_or_default()
        };
        let hw = hv.device(fpga).unwrap().fpga.lock().unwrap();
        for region in hw.regions() {
            let expected_owned = owned.contains(&region.id);
            match region.lifecycle {
                LifecycleState::Free => assert!(
                    !expected_owned,
                    "{} is Free but owned",
                    region.id
                ),
                LifecycleState::Reserved | LifecycleState::Active => {
                    assert!(
                        expected_owned,
                        "{} is {} but unowned",
                        region.id,
                        region.lifecycle
                    )
                }
                other => panic!(
                    "{} settled in transient state {other}",
                    region.id
                ),
            }
        }
    }
}

#[test]
fn no_interleaving_records_an_illegal_transition() {
    // Ops are drawn as bytes; each case replays a fresh cluster.
    let gen = Gen::new(|rng, size| {
        let len = rng.next_below(size as u64 + 1) as usize + 4;
        (0..len).map(|_| rng.next_below(64) as u8).collect::<Vec<u8>>()
    });
    forall(0xC3E4, 48, &gen, |ops| {
        let sched = sched_on(&ClusterConfig::sched_testbed());
        let user = sched.hv().add_user("prop");
        let mut leases: Vec<Lease> = Vec::new();
        for op in ops {
            match op % 6 {
                // Admit one region.
                0 | 1 => {
                    if let Ok(lease) = sched.admit(&AdmissionRequest::new(
                        user,
                        ServiceModel::BAaaS,
                        RequestClass::Batch,
                    )) {
                        leases.push(lease);
                    }
                }
                // Admit a gang of two.
                2 => {
                    if let Ok(lease) = sched.admit(
                        &AdmissionRequest::new(
                            user,
                            ServiceModel::BAaaS,
                            RequestClass::Batch,
                        )
                        .gang(2),
                    ) {
                        leases.push(lease);
                    }
                }
                // Program a member of some lease (idempotent-ish:
                // reprogramming an Active region is legal).
                3 => {
                    if let Some(lease) =
                        leases.get((*op as usize / 6) % leases.len().max(1))
                    {
                        let idx = *op as usize % lease.regions();
                        let _ =
                            lease.program_member(idx, &mm16_partial(0));
                    }
                }
                // Relocate a whole lease (single or gang).
                4 => {
                    if let Some(lease) =
                        leases.get((*op as usize / 6) % leases.len().max(1))
                    {
                        let _ = sched.relocate_gang(lease.token());
                    }
                }
                // Release a lease.
                _ => {
                    if !leases.is_empty() {
                        let idx = (*op as usize / 6) % leases.len();
                        let lease = leases.swap_remove(idx);
                        let _ = lease.release();
                    }
                }
            }
        }
        drop(leases); // release everything still held
        assert_log_legal(&sched);
        assert_settled(&sched);
        if sched.hv().metrics.counter("sched.preempt.raced").get() != 0 {
            return Err("preemption race absorbed — quiesce broken"
                .to_string());
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn threaded_gang_relocation_is_atomic() {
    let sched = sched_on(&ClusterConfig::sched_testbed());
    let user = sched.hv().add_user("gang");
    let gang = sched
        .admit(
            &AdmissionRequest::new(
                user,
                ServiceModel::BAaaS,
                RequestClass::Batch,
            )
            .gang(2)
            .co_located(),
        )
        .unwrap();
    for i in 0..2 {
        gang.program_member(i, &mm16_partial(0)).unwrap();
    }
    let token = gang.token();
    std::thread::scope(|scope| {
        // Worker: keeps reprogramming the gang members (pins regions
        // mid-flight, chasing the gang across relocations).
        let worker_gang = &gang;
        scope.spawn(move || {
            for i in 0..40 {
                worker_gang
                    .program_member(i % 2, &mm16_partial(0))
                    .expect("reprogram never races a relocation");
            }
        });
        // Relocator: bounces the gang between the two devices. A
        // pinned member makes the whole relocation fail cleanly —
        // all-or-nothing, never partial.
        let relocator = &sched;
        scope.spawn(move || {
            for _ in 0..15 {
                match relocator.relocate_gang(token) {
                    Ok(reports) => assert_eq!(
                        reports.len(),
                        2,
                        "partial gang relocation observed"
                    ),
                    Err(_) => std::thread::yield_now(),
                }
            }
        });
    });
    // Both members still live, programmed, co-owned — and on one
    // device's worth of placements each.
    let placements = gang.placements();
    assert_eq!(placements.len(), 2);
    assert_log_legal(&sched);
    assert_eq!(
        sched.hv().metrics.counter("sched.preempt.raced").get(),
        0
    );
    // Regions quiesce cleanly once the threads are done.
    for p in &placements {
        if let rc3e::sched::GrantTarget::Vfpga(v, _, _) = p.target {
            assert!(sched.hv().guards().is_quiescable(v));
        }
    }
    gang.release().unwrap();
    assert_settled(&sched);
}

#[test]
fn preemption_storm_never_trips_the_raced_counter() {
    if !rc3e::testing::artifacts_available(
        "lifecycle::preemption_storm_never_trips_the_raced_counter",
    ) {
        return;
    }
    let sched = sched_on(&ClusterConfig::sched_testbed());
    let baaas = BaaasService::with_scheduler(Arc::clone(&sched));
    baaas.hv.register_service("mm16", mm16_partial(0));
    let vip = sched.hv().add_user("vip");
    std::thread::scope(|scope| {
        // Background invokers: program + stream inside the (now
        // defense-in-depth) preemption-retry wrapper.
        for i in 0..3 {
            let svc = &baaas;
            let name = format!("invoker-{i}");
            scope.spawn(move || {
                let user = svc.hv.add_user(&name);
                for _ in 0..3 {
                    svc.invoke(
                        user,
                        "mm16",
                        &StreamConfig::matmul16(256),
                    )
                    .expect("invocation survives the storm");
                }
            });
        }
        // Interactive storm: admissions that preempt quiescable batch
        // victims; pinned (streaming) victims are skipped, so some
        // attempts fail NoCapacity — that is the contract.
        let s = &sched;
        scope.spawn(move || {
            for _ in 0..12 {
                if let Ok(lease) = s.admit(&AdmissionRequest::new(
                    vip,
                    ServiceModel::RAaaS,
                    RequestClass::Interactive,
                )) {
                    let _ = lease.release();
                }
                std::thread::yield_now();
            }
        });
    });
    assert_eq!(
        sched.hv().metrics.counter("sched.preempt.raced").get(),
        0,
        "the quiesce discipline must make the setup race impossible"
    );
    assert_log_legal(&sched);
    assert_settled(&sched);
}

#[test]
fn preemption_scenario_keeps_raced_counter_zero() {
    // The classic preemption scenario, rerun under the lifecycle
    // rules: quiesce-won migration, no retry fired, telemetry sane.
    let sched = sched_on(&ClusterConfig::sched_testbed());
    let batcher = sched.hv().add_user("batcher");
    let vip = sched.hv().add_user("vip");
    let _grants = fill_batch_leases(&sched, batcher, 4);
    let lease = sched
        .admit(&AdmissionRequest::new(
            vip,
            ServiceModel::RAaaS,
            RequestClass::Interactive,
        ))
        .unwrap();
    assert_eq!(
        sched.hv().metrics.counter("sched.preemptions").get(),
        1
    );
    assert_eq!(
        sched.hv().metrics.counter("sched.preempt.raced").get(),
        0
    );
    // The quiesce win was recorded (zero wall wait on the fast path).
    assert!(
        sched
            .hv()
            .metrics
            .histogram("sched.preempt.quiesce_wait")
            .count()
            >= 1
    );
    assert_log_legal(&sched);
    lease.release().unwrap();
}
