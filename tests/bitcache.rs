//! Integration tests for the cluster bitstream cache + AOT compile
//! service: the cold → warm → resident program-latency tiers over
//! the wire, compile coalescing under concurrent submits, the
//! `agent.fetch_bitstream` transfer plane (binary and base64), and
//! an LRU + persistence property test against the on-disk store.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};

use rc3e::bitcache::{BitstreamCache, CacheKey};
use rc3e::bitstream::BitstreamBuilder;
use rc3e::fpga::resources::Resources;
use rc3e::hls::flow::region_window;
use rc3e::hypervisor::Hypervisor;
use rc3e::metrics::Registry;
use rc3e::middleware::api::{CompileSubmitRequest, ErrorCode};
use rc3e::middleware::{Client, ManagementServer};
use rc3e::testing::prop::{forall, Gen};
use rc3e::util::clock::VirtualClock;

struct Cloud {
    server: ManagementServer,
    client: Client,
}

fn cloud() -> Cloud {
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
    );
    let server = ManagementServer::spawn(hv, 69.0).unwrap();
    let client = Client::connect(server.addr()).unwrap();
    Cloud { server, client }
}

/// Counter value from a metrics export, 0 when unregistered.
fn counter(c: &mut Client, name: &str) -> u64 {
    c.metrics_export()
        .unwrap()
        .counters
        .iter()
        .find(|(n, _)| n.as_str() == name)
        .map(|(_, v)| *v)
        .unwrap_or(0)
}

// ================================================== latency tiers

/// The tentpole contract: cold (flow + PR) must dwarf warm (PR
/// only), which must dwarf resident (no reconfiguration at all) —
/// and all three tiers must be visible as `bitcache.*` counters on
/// the operator metrics surface.
#[test]
fn cold_warm_resident_program_tiers() {
    let mut c = cloud();
    let user = c.client.add_user("tenant").unwrap().user;
    let lease = c.client.alloc_vfpga(user, None, None).unwrap();

    // An uncompiled core programs from the prebuilt library — a
    // cache miss, not an error.
    c.client.program_core(user, lease.alloc, "loopback").unwrap();
    assert!(counter(&mut c.client, "bitcache.miss") >= 1);

    // Cold: one AOT flow run (background job on the build server's
    // private clock), then PR on first use of the artifact.
    let sub = c
        .client
        .compile_submit(&CompileSubmitRequest {
            user,
            core: "matmul16".to_string(),
            part: None,
        })
        .unwrap();
    assert_eq!(sub.state, "submitted");
    let result = c.client.job_wait_done(sub.job.unwrap()).unwrap();
    assert_eq!(result.get("digest").as_str().unwrap(), sub.digest);
    let build_ms = result.get("build_ms").as_f64().unwrap();

    // Warm: the artifact is cached, programming pays only PR.
    let warm =
        c.client.program_core(user, lease.alloc, "matmul16").unwrap();
    assert!(warm.pr_ms > 0.0, "warm PR must cost real time");
    assert!(counter(&mut c.client, "bitcache.hit") >= 1);
    let cold_ms = build_ms + warm.pr_ms;

    // Resident: the region already holds this exact design — the
    // hypervisor skips reconfiguration entirely.
    let resident =
        c.client.program_core(user, lease.alloc, "matmul16").unwrap();
    assert_eq!(resident.pr_ms, 0.0);
    assert!(counter(&mut c.client, "bitcache.resident_skip") >= 1);

    // Tier ordering (the acceptance floor is 5x / 20x; the model
    // puts the true ratios orders of magnitude higher).
    assert!(
        cold_ms >= 5.0 * warm.pr_ms,
        "cold {cold_ms} ms vs warm {} ms",
        warm.pr_ms
    );
    assert!(cold_ms >= 20.0 * resident.pr_ms.max(1.0));

    // The digest now answers `cached` without a job.
    let status = c.client.compile_status(&sub.digest).unwrap();
    assert_eq!(status.state, "cached");
    assert_eq!(status.job, None);
}

// ==================================================== coalescing

/// N tenants racing `compile_submit` for one digest share a single
/// flow run: every ticket names the same digest and the server runs
/// the HLS flow exactly once.
#[test]
fn concurrent_submits_coalesce_to_one_flow_run() {
    let mut c = cloud();
    let addr = c.server.addr();
    const N: usize = 4;
    let barrier = Arc::new(Barrier::new(N));
    let digests: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|i| {
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let user = client
                        .add_user(&format!("racer-{i}"))
                        .unwrap()
                        .user;
                    barrier.wait();
                    let sub = client
                        .compile_submit(&CompileSubmitRequest {
                            user,
                            core: "saxpy".to_string(),
                            part: None,
                        })
                        .unwrap();
                    assert!(matches!(
                        sub.state.as_str(),
                        "submitted" | "coalesced" | "cached"
                    ));
                    if let Some(job) = sub.job {
                        client.job_wait_done(job).unwrap();
                    }
                    sub.digest
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(digests.iter().all(|d| d == &digests[0]));
    assert_eq!(
        counter(&mut c.client, "bitcache.compile_runs"),
        1,
        "coalescing must collapse {N} submits into one flow run"
    );
    // And a late submit finds the artifact already cached.
    let user = c.client.add_user("late").unwrap().user;
    let late = c
        .client
        .compile_submit(&CompileSubmitRequest {
            user,
            core: "saxpy".to_string(),
            part: None,
        })
        .unwrap();
    assert_eq!(late.state, "cached");
    assert_eq!(late.digest, digests[0]);
}

// ============================================== artifact transfer

/// `agent.fetch_bitstream` over both wire encodings: protocol-4
/// binary data frames and the protocol-3 base64 fallback must
/// reassemble byte-identical, CRC-clean artifacts.
#[test]
fn fetch_bitstream_binary_and_base64_agree() {
    let mut c = cloud();
    let part = "xc7vx485t";
    let bin = c.client.fetch_bitstream("matmul16", part, None).unwrap();
    assert!(bin.crc_ok());
    assert_eq!(bin.meta.core, "matmul16");
    assert!(!bin.payload.is_empty());

    c.client.set_proto(3);
    let b64 = c.client.fetch_bitstream("matmul16", part, None).unwrap();
    assert!(b64.crc_ok());
    assert_eq!(b64.sha256, bin.sha256);
    assert_eq!(b64.payload, bin.payload);

    let err = c
        .client
        .fetch_bitstream("no_such_core", part, None)
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownCore);
}

// ======================================= LRU + persistence (prop)

const PROP_CORES: [&str; 6] =
    ["alpha", "beta", "gamma", "delta", "eps", "zeta"];
const PROP_CAP: usize = 3;

#[derive(Debug, Clone, Copy)]
enum Op {
    Admit(usize),
    Lookup(usize),
}

fn prop_state_dir(tag: u64) -> PathBuf {
    std::env::temp_dir().join(format!(
        "rc3e-bitcache-prop-{}-{tag}",
        std::process::id()
    ))
}

/// Random admit/lookup sequences against a capacity-3 store, checked
/// against a reference LRU model, then reopened from disk: the
/// surviving set must match the model exactly and every reloaded
/// artifact must still pass CRC.
#[test]
fn lru_eviction_and_persistence_survive_restart() {
    let gen = Gen::new(|rng: &mut rc3e::util::rng::Rng, size| {
        let len = 4 + rng.next_below(4 * size as u64 + 1) as usize;
        (0..len)
            .map(|_| {
                let core =
                    rng.next_below(PROP_CORES.len() as u64) as usize;
                if rng.chance(0.4) {
                    Op::Lookup(core)
                } else {
                    Op::Admit(core)
                }
            })
            .collect::<Vec<Op>>()
    });
    let case = AtomicU64::new(0);
    forall(0xB17CA, 30, &gen, |ops| {
        let dir = prop_state_dir(case.fetch_add(1, Ordering::Relaxed));
        let _ = std::fs::remove_dir_all(&dir);
        let verdict = check_lru_case(ops, &dir);
        let _ = std::fs::remove_dir_all(&dir);
        verdict
    })
    .unwrap();
}

fn prop_bs(core: &str) -> rc3e::bitstream::Bitstream {
    BitstreamBuilder::partial("xc7vx485t", core)
        .resources(Resources::new(100, 100, 1, 1))
        .frames(region_window(0, 1))
        .payload_seed(0xB5 ^ core.len() as u64)
        .build()
}

fn check_lru_case(ops: &[Op], dir: &Path) -> Result<(), String> {
    let cache = BitstreamCache::open(
        PROP_CAP,
        Some(dir),
        Arc::new(Registry::new()),
    );
    // Reference model: digest → last-touch tick, exact LRU.
    let mut model: Vec<(String, u64)> = Vec::new();
    let mut tick = 0u64;
    for op in ops {
        tick += 1;
        match *op {
            Op::Admit(i) => {
                let core = PROP_CORES[i];
                let key = CacheKey::new(core, "xc7vx485t");
                let digest = cache
                    .admit(&key, prop_bs(core), region_window(0, 1))
                    .map_err(|e| format!("admit {core}: {e}"))?;
                model.retain(|(d, _)| d != &digest);
                model.push((digest, tick));
                if model.len() > PROP_CAP {
                    let victim = model
                        .iter()
                        .min_by_key(|(_, t)| *t)
                        .unwrap()
                        .0
                        .clone();
                    model.retain(|(d, _)| d != &victim);
                }
            }
            Op::Lookup(i) => {
                let digest =
                    CacheKey::new(PROP_CORES[i], "xc7vx485t").digest();
                let got = cache.lookup(&digest);
                let want = model.iter().any(|(d, _)| d == &digest);
                if got.is_some() != want {
                    return Err(format!(
                        "lookup {}: cache {} but model {}",
                        PROP_CORES[i],
                        if got.is_some() { "hit" } else { "missed" },
                        if want { "holds it" } else { "does not" },
                    ));
                }
                if want {
                    model.retain(|(d, _)| d != &digest);
                    model.push((digest, tick));
                }
            }
        }
    }
    if cache.len() > PROP_CAP {
        return Err(format!("over capacity: {}", cache.len()));
    }
    // Recency order must match the model (most-recent last).
    let mut want: Vec<(String, u64)> = model.clone();
    want.sort_by_key(|(_, t)| *t);
    let got: Vec<String> =
        cache.keys().iter().map(|k| k.digest()).collect();
    let want: Vec<String> = want.into_iter().map(|(d, _)| d).collect();
    if got != want {
        return Err(format!("LRU order {got:?} != model {want:?}"));
    }
    // Restart: a reopened cache must hold exactly the survivors,
    // each still CRC-clean.
    drop(cache);
    let reopened = BitstreamCache::open(
        PROP_CAP,
        Some(dir),
        Arc::new(Registry::new()),
    );
    if reopened.len() != model.len() {
        return Err(format!(
            "reopened {} entries, model {}",
            reopened.len(),
            model.len()
        ));
    }
    for (digest, _) in &model {
        match reopened.lookup(digest) {
            Some(bs) if bs.crc_ok() => {}
            Some(_) => {
                return Err(format!("{digest} reloaded corrupt"))
            }
            None => {
                return Err(format!("{digest} lost across restart"))
            }
        }
    }
    Ok(())
}
