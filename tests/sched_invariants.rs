//! Scheduler invariants under contention, property-tested with the
//! in-tree framework (`rc3e::testing::prop`).
//!
//! Invariants:
//! * quotas: a tenant's concurrent vFPGA-equivalents never exceed its
//!   `max_concurrent` under arbitrary submit/release interleavings;
//! * liveness: once everything held is released, every queued request
//!   resolves — no ready request starves;
//! * fairness: stride scheduling gives a weight-4 tenant 4× the
//!   admissions of a weight-1 tenant over a contended window;
//! * preemption: an interactive service lease lands on a full cluster
//!   by relocating a batch lease via migration;
//! * threads: 8 tenants × 3 jobs against 4 regions (6× capacity) all
//!   complete through the blocking admission path.

use std::sync::Arc;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::sched::{RequestClass, SchedGrant, Scheduler, TenantQuota};
use rc3e::service::RaaasService;
use rc3e::testing::prop::{forall, Gen};
use rc3e::util::clock::{VirtualClock, VirtualTime};
use rc3e::util::ids::{TicketId, UserId};

fn boot(config: &ClusterConfig) -> Arc<Scheduler> {
    let hv = Arc::new(
        Hypervisor::boot(
            config,
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    Scheduler::new(hv)
}

/// Move resolved tickets into `held`; error on failed tickets.
fn collect(
    sched: &Scheduler,
    tickets: &mut Vec<TicketId>,
    held: &mut Vec<SchedGrant>,
) -> Result<(), String> {
    let mut i = 0;
    while i < tickets.len() {
        match sched.try_claim(tickets[i]) {
            Some(Ok(grant)) => {
                held.push(grant);
                tickets.remove(i);
            }
            Some(Err(e)) => return Err(format!("ticket failed: {e}")),
            None => i += 1,
        }
    }
    Ok(())
}

#[test]
fn prop_quotas_hold_and_nothing_starves() {
    // Ops: 0..=2 submit for tenant op; 3..=5 release a held grant.
    let gen = Gen::new(|rng: &mut rc3e::util::rng::Rng, size| {
        let len = rng.next_below(size as u64 * 2 + 1) as usize;
        (0..len).map(|_| rng.next_below(6)).collect::<Vec<u64>>()
    });
    let quotas: [u64; 3] = [1, 2, 3];
    forall(0xC0FFEE, 40, &gen, |ops: &Vec<u64>| {
        let sched = boot(&ClusterConfig::single_vc707());
        let users: Vec<UserId> = (0..3)
            .map(|i| {
                let u = sched.hv().add_user(&format!("tenant-{i}"));
                sched.set_quota(
                    u,
                    TenantQuota {
                        max_concurrent: quotas[i],
                        weight: (i + 1) as u64,
                        ..TenantQuota::default()
                    },
                );
                u
            })
            .collect();
        let mut held: Vec<SchedGrant> = Vec::new();
        let mut tickets: Vec<TicketId> = Vec::new();
        let check_quotas = |sched: &Scheduler| -> Result<(), String> {
            for (i, u) in users.iter().enumerate() {
                let in_use = sched.in_use(*u);
                if in_use > quotas[i] {
                    return Err(format!(
                        "tenant {i} holds {in_use} > quota {}",
                        quotas[i]
                    ));
                }
            }
            Ok(())
        };
        for &op in ops {
            match op {
                0..=2 => {
                    tickets.push(sched.submit(
                        users[op as usize],
                        ServiceModel::RAaaS,
                        RequestClass::Batch,
                    ));
                }
                _ => {
                    if !held.is_empty() {
                        let idx = op as usize % held.len();
                        let grant = held.remove(idx);
                        sched
                            .release(grant.alloc)
                            .map_err(|e| e.to_string())?;
                    }
                }
            }
            collect(&sched, &mut tickets, &mut held)?;
            check_quotas(&sched)?;
        }
        // Drain: releasing everything must resolve every ticket.
        let mut rounds = 0usize;
        loop {
            collect(&sched, &mut tickets, &mut held)?;
            if tickets.is_empty() {
                break;
            }
            if held.is_empty() {
                return Err(format!(
                    "starvation: {} tickets queued with all capacity free",
                    tickets.len()
                ));
            }
            let grant = held.remove(0);
            sched.release(grant.alloc).map_err(|e| e.to_string())?;
            check_quotas(&sched)?;
            rounds += 1;
            if rounds > 10_000 {
                return Err("drain did not converge".to_string());
            }
        }
        for grant in held.drain(..) {
            sched.release(grant.alloc).map_err(|e| e.to_string())?;
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn fair_share_honors_weights_four_to_one() {
    let sched = boot(&ClusterConfig::single_vc707());
    let filler = sched.hv().add_user("filler");
    let heavy = sched.hv().add_user("heavy");
    let light = sched.hv().add_user("light");
    sched.set_quota(
        heavy,
        TenantQuota {
            weight: 4,
            ..TenantQuota::default()
        },
    );
    sched.set_quota(
        light,
        TenantQuota {
            weight: 1,
            ..TenantQuota::default()
        },
    );
    // Occupy all 4 regions so every subsequent request queues.
    let mut fills = Vec::new();
    for _ in 0..4 {
        fills.push(
            sched
                .acquire_vfpga(
                    filler,
                    ServiceModel::RAaaS,
                    RequestClass::Normal,
                )
                .unwrap(),
        );
    }
    let mut tickets: Vec<TicketId> = Vec::new();
    for _ in 0..10 {
        tickets.push(sched.submit(
            heavy,
            ServiceModel::RAaaS,
            RequestClass::Batch,
        ));
    }
    for _ in 0..10 {
        tickets.push(sched.submit(
            light,
            ServiceModel::RAaaS,
            RequestClass::Batch,
        ));
    }
    // Free one region, then recycle each admitted lease: grants
    // emerge one at a time in fair-share order.
    sched.release(fills.pop().unwrap().alloc).unwrap();
    let mut order: Vec<UserId> = Vec::new();
    for _ in 0..10 {
        let mut held = Vec::new();
        collect(&sched, &mut tickets, &mut held).unwrap();
        assert_eq!(held.len(), 1, "exactly one grant per free region");
        let grant = held.pop().unwrap();
        order.push(grant.user);
        sched.release(grant.alloc).unwrap();
    }
    let heavy_n = order.iter().filter(|u| **u == heavy).count();
    let light_n = order.iter().filter(|u| **u == light).count();
    assert_eq!(
        heavy_n, 8,
        "weight-4 tenant should take 8 of the first 10 grants \
         (got {heavy_n} heavy / {light_n} light)"
    );
}

#[test]
fn interactive_service_lease_preempts_batch_on_full_cluster() {
    let sched = boot(&ClusterConfig::sched_testbed());
    let raaas = RaaasService::with_scheduler(Arc::clone(&sched));
    let batcher = sched.hv().add_user("batcher");
    // Fill the only RAaaS-capable device with programmed batch work.
    rc3e::testing::fill_batch_leases(&sched, batcher, 4);
    // The interactive RAaaS façade lease triggers a migration-based
    // preemption and lands.
    let vip = sched.hv().add_user("vip");
    let (alloc, _vfpga) = raaas.alloc(vip).unwrap();
    assert_eq!(sched.hv().metrics.counter("sched.preemptions").get(), 1);
    assert_eq!(sched.hv().metrics.counter("hv.migrations").get(), 1);
    assert_eq!(sched.usage(batcher).preempted, 1);
    raaas.release(alloc).unwrap();
}

#[test]
fn threaded_contention_six_times_capacity_completes() {
    let sched = boot(&ClusterConfig::single_vc707());
    let tenants: Vec<UserId> = (0..8)
        .map(|i| {
            let u = sched.hv().add_user(&format!("storm-{i}"));
            sched.set_quota(
                u,
                TenantQuota {
                    max_concurrent: 1,
                    ..TenantQuota::default()
                },
            );
            u
        })
        .collect();
    std::thread::scope(|scope| {
        for &user in &tenants {
            let sched = Arc::clone(&sched);
            scope.spawn(move || {
                for _ in 0..3 {
                    let grant = sched
                        .acquire_vfpga_blocking(
                            user,
                            ServiceModel::RAaaS,
                            RequestClass::Batch,
                        )
                        .unwrap();
                    assert!(
                        sched.in_use(user) <= 1,
                        "quota exceeded mid-flight"
                    );
                    // Simulate work.
                    sched
                        .hv()
                        .clock
                        .advance(VirtualTime::from_millis_f64(50.0));
                    sched.release(grant.alloc).unwrap();
                }
            });
        }
    });
    // Everyone finished; the cluster is empty again.
    assert!(sched.active_grants().is_empty());
    let granted = sched.hv().metrics.counter("sched.granted").get();
    assert_eq!(granted, 24, "8 tenants x 3 jobs all admitted");
    for u in &tenants {
        assert_eq!(sched.usage(*u).granted, 3);
        assert_eq!(sched.usage(*u).released, 3);
        assert!(sched.usage(*u).device_seconds > 0.0);
    }
}

#[test]
fn reservation_expiry_is_reclaimed_for_queued_work() {
    let sched = boot(&ClusterConfig::single_vc707());
    let holder = sched.hv().add_user("holder");
    let worker = sched.hv().add_user("worker");
    let now = sched.hv().clock.now();
    // Reserve the whole device for 100 virtual seconds, never claim.
    sched.reserve(holder, 4, now, VirtualTime::from_secs_f64(100.0));
    let ticket =
        sched.submit(worker, ServiceModel::RAaaS, RequestClass::Batch);
    assert!(sched.try_claim(ticket).is_none(), "withheld while reserved");
    // Let the window lapse; the next admission attempt reaps it.
    sched.hv().clock.advance(VirtualTime::from_secs_f64(200.0));
    let g2 = sched
        .acquire_vfpga(worker, ServiceModel::RAaaS, RequestClass::Normal)
        .unwrap();
    // The queued ticket was pumped in by the same reclamation.
    let first = sched
        .try_claim(ticket)
        .expect("queued request admitted after expiry")
        .unwrap();
    sched.release(first.alloc).unwrap();
    sched.release(g2.alloc).unwrap();
}
