//! Scheduler invariants under contention, property-tested with the
//! in-tree framework (`rc3e::testing::prop`).
//!
//! Invariants:
//! * quotas: a tenant's concurrent vFPGA-equivalents never exceed its
//!   `max_concurrent` under arbitrary submit/release interleavings;
//! * liveness: once everything held is released, every queued request
//!   resolves — no ready request starves;
//! * fairness: stride scheduling gives a weight-4 tenant 4× the
//!   admissions of a weight-1 tenant over a contended window;
//! * preemption: an interactive service lease lands on a full cluster
//!   by relocating a batch lease via migration;
//! * threads: 8 tenants × 3 jobs against 4 regions (6× capacity) all
//!   complete through the blocking admission path;
//! * gang atomicity: under threaded contention, a tenant whose every
//!   admission is an N-gang is only ever observed holding multiples
//!   of N — no partial gang is ever visible, and quotas count the
//!   whole gang;
//! * capability tokens: a forged or stale `LeaseToken` is rejected
//!   (`bad_token`) on every mutating v2 RPC instead of the server
//!   trusting the honor-system `user` field.

use std::sync::Arc;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::middleware::api::ErrorCode;
use rc3e::middleware::{Client, ManagementServer};
use rc3e::sched::{
    AdmissionRequest, Lease, RequestClass, Scheduler, TenantQuota,
};
use rc3e::service::RaaasService;
use rc3e::testing::prop::{forall, Gen};
use rc3e::util::clock::{VirtualClock, VirtualTime};
use rc3e::util::ids::{LeaseToken, TicketId, UserId};

fn boot(config: &ClusterConfig) -> Arc<Scheduler> {
    let hv = Arc::new(
        Hypervisor::boot(
            config,
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    Scheduler::new(hv)
}

fn one(
    user: UserId,
    model: ServiceModel,
    class: RequestClass,
) -> AdmissionRequest {
    AdmissionRequest::new(user, model, class)
}

/// Move resolved tickets into `held`; error on failed tickets.
fn collect(
    sched: &Arc<Scheduler>,
    tickets: &mut Vec<TicketId>,
    held: &mut Vec<Lease>,
) -> Result<(), String> {
    let mut i = 0;
    while i < tickets.len() {
        match sched.poll_ticket(tickets[i]) {
            Some(Ok(lease)) => {
                held.push(lease);
                tickets.remove(i);
            }
            Some(Err(e)) => return Err(format!("ticket failed: {e}")),
            None => i += 1,
        }
    }
    Ok(())
}

#[test]
fn prop_quotas_hold_and_nothing_starves() {
    // Ops: 0..=2 submit for tenant op; 3..=5 release a held lease.
    let gen = Gen::new(|rng: &mut rc3e::util::rng::Rng, size| {
        let len = rng.next_below(size as u64 * 2 + 1) as usize;
        (0..len).map(|_| rng.next_below(6)).collect::<Vec<u64>>()
    });
    let quotas: [u64; 3] = [1, 2, 3];
    forall(0xC0FFEE, 40, &gen, |ops: &Vec<u64>| {
        let sched = boot(&ClusterConfig::single_vc707());
        let users: Vec<UserId> = (0..3)
            .map(|i| {
                let u = sched.hv().add_user(&format!("tenant-{i}"));
                sched.set_quota(
                    u,
                    TenantQuota {
                        max_concurrent: quotas[i],
                        weight: (i + 1) as u64,
                        ..TenantQuota::default()
                    },
                );
                u
            })
            .collect();
        let mut held: Vec<Lease> = Vec::new();
        let mut tickets: Vec<TicketId> = Vec::new();
        let check_quotas = |sched: &Scheduler| -> Result<(), String> {
            for (i, u) in users.iter().enumerate() {
                let in_use = sched.in_use(*u);
                if in_use > quotas[i] {
                    return Err(format!(
                        "tenant {i} holds {in_use} > quota {}",
                        quotas[i]
                    ));
                }
            }
            Ok(())
        };
        for &op in ops {
            match op {
                0..=2 => {
                    tickets.push(sched.enqueue(&one(
                        users[op as usize],
                        ServiceModel::RAaaS,
                        RequestClass::Batch,
                    )));
                }
                _ => {
                    if !held.is_empty() {
                        let idx = op as usize % held.len();
                        let lease = held.remove(idx);
                        lease.release().map_err(|e| e.to_string())?;
                    }
                }
            }
            collect(&sched, &mut tickets, &mut held)?;
            check_quotas(&sched)?;
        }
        // Drain: releasing everything must resolve every ticket.
        let mut rounds = 0usize;
        loop {
            collect(&sched, &mut tickets, &mut held)?;
            if tickets.is_empty() {
                break;
            }
            if held.is_empty() {
                return Err(format!(
                    "starvation: {} tickets queued with all capacity free",
                    tickets.len()
                ));
            }
            let lease = held.remove(0);
            lease.release().map_err(|e| e.to_string())?;
            check_quotas(&sched)?;
            rounds += 1;
            if rounds > 10_000 {
                return Err("drain did not converge".to_string());
            }
        }
        for lease in held.drain(..) {
            lease.release().map_err(|e| e.to_string())?;
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_gang_admissions_are_atomic_under_contention() {
    // Two gang tenants (gang sizes 2 and 4) and a single-region
    // tenant hammer a 4-region device from threads. At every
    // observation point each gang tenant's in-use count must be a
    // multiple of its gang size — a partial gang observable anywhere
    // is a two-phase-reservation bug.
    let sched = boot(&ClusterConfig::single_vc707());
    let pair = sched.hv().add_user("pair");
    let quad = sched.hv().add_user("quad");
    let solo = sched.hv().add_user("solo");
    // Quotas count the whole gang: cap `pair` at exactly one gang.
    sched.set_quota(
        pair,
        TenantQuota {
            max_concurrent: 2,
            ..TenantQuota::default()
        },
    );
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| {
        let observer = {
            let sched = Arc::clone(&sched);
            let stop = &stop;
            scope.spawn(move || {
                let mut checks = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let p = sched.in_use(pair);
                    let q = sched.in_use(quad);
                    assert!(
                        p % 2 == 0 && p <= 2,
                        "partial pair gang observable: {p}"
                    );
                    assert!(
                        q % 4 == 0,
                        "partial quad gang observable: {q}"
                    );
                    checks += 1;
                    std::thread::yield_now();
                }
                assert!(checks > 0);
            })
        };
        for (user, n, jobs) in
            [(pair, 2u32, 12usize), (quad, 4, 8), (solo, 1, 16)]
        {
            let sched = Arc::clone(&sched);
            scope.spawn(move || {
                for _ in 0..jobs {
                    let lease = sched
                        .admit_blocking(
                            &one(
                                user,
                                ServiceModel::RAaaS,
                                RequestClass::Batch,
                            )
                            .gang(n),
                        )
                        .unwrap();
                    assert_eq!(lease.regions(), n as usize);
                    sched
                        .hv()
                        .clock
                        .advance(VirtualTime::from_millis_f64(10.0));
                    lease.release().unwrap();
                }
            });
        }
        // Scoped threads join at the end of the closure; flag the
        // observer down once the workers are done by joining them
        // first via a nested scope ordering trick: spawn a watchdog
        // that flips `stop` when all worker leases settle.
        let sched2 = Arc::clone(&sched);
        let stop_ref = &stop;
        scope.spawn(move || {
            // 12*2 + 8*4 + 16*1 = 72 releases in total.
            while sched2.hv().metrics.counter("sched.released").get() < 72 {
                std::thread::yield_now();
            }
            stop_ref.store(true, std::sync::atomic::Ordering::Relaxed);
        });
        let _ = observer;
    });
    assert!(sched.active_grants().is_empty());
    assert_eq!(sched.in_use(pair), 0);
    assert_eq!(sched.in_use(quad), 0);
}

#[test]
fn fair_share_honors_weights_four_to_one() {
    let sched = boot(&ClusterConfig::single_vc707());
    let filler = sched.hv().add_user("filler");
    let heavy = sched.hv().add_user("heavy");
    let light = sched.hv().add_user("light");
    sched.set_quota(
        heavy,
        TenantQuota {
            weight: 4,
            ..TenantQuota::default()
        },
    );
    sched.set_quota(
        light,
        TenantQuota {
            weight: 1,
            ..TenantQuota::default()
        },
    );
    // Occupy all 4 regions so every subsequent request queues.
    let mut fills = Vec::new();
    for _ in 0..4 {
        fills.push(
            sched
                .admit(&one(
                    filler,
                    ServiceModel::RAaaS,
                    RequestClass::Normal,
                ))
                .unwrap(),
        );
    }
    let mut tickets: Vec<TicketId> = Vec::new();
    for _ in 0..10 {
        tickets.push(sched.enqueue(&one(
            heavy,
            ServiceModel::RAaaS,
            RequestClass::Batch,
        )));
    }
    for _ in 0..10 {
        tickets.push(sched.enqueue(&one(
            light,
            ServiceModel::RAaaS,
            RequestClass::Batch,
        )));
    }
    // Free one region, then recycle each admitted lease: grants
    // emerge one at a time in fair-share order.
    fills.pop().unwrap().release().unwrap();
    let mut order: Vec<UserId> = Vec::new();
    for _ in 0..10 {
        let mut held = Vec::new();
        collect(&sched, &mut tickets, &mut held).unwrap();
        assert_eq!(held.len(), 1, "exactly one grant per free region");
        let lease = held.pop().unwrap();
        order.push(lease.tenant());
        lease.release().unwrap();
    }
    let heavy_n = order.iter().filter(|u| **u == heavy).count();
    let light_n = order.iter().filter(|u| **u == light).count();
    assert_eq!(
        heavy_n, 8,
        "weight-4 tenant should take 8 of the first 10 grants \
         (got {heavy_n} heavy / {light_n} light)"
    );
}

#[test]
fn interactive_service_lease_preempts_batch_on_full_cluster() {
    let sched = boot(&ClusterConfig::sched_testbed());
    let raaas = RaaasService::with_scheduler(Arc::clone(&sched));
    let batcher = sched.hv().add_user("batcher");
    // Fill the only RAaaS-capable device with programmed batch work.
    rc3e::testing::fill_batch_leases(&sched, batcher, 4);
    // The interactive RAaaS façade lease triggers a migration-based
    // preemption and lands.
    let vip = sched.hv().add_user("vip");
    let lease = raaas.alloc(vip).unwrap();
    assert_eq!(sched.hv().metrics.counter("sched.preemptions").get(), 1);
    assert_eq!(sched.hv().metrics.counter("hv.migrations").get(), 1);
    assert_eq!(sched.usage(batcher).preempted, 1);
    lease.release().unwrap();
}

#[test]
fn threaded_contention_six_times_capacity_completes() {
    let sched = boot(&ClusterConfig::single_vc707());
    let tenants: Vec<UserId> = (0..8)
        .map(|i| {
            let u = sched.hv().add_user(&format!("storm-{i}"));
            sched.set_quota(
                u,
                TenantQuota {
                    max_concurrent: 1,
                    ..TenantQuota::default()
                },
            );
            u
        })
        .collect();
    std::thread::scope(|scope| {
        for &user in &tenants {
            let sched = Arc::clone(&sched);
            scope.spawn(move || {
                for _ in 0..3 {
                    let lease = sched
                        .admit_blocking(&one(
                            user,
                            ServiceModel::RAaaS,
                            RequestClass::Batch,
                        ))
                        .unwrap();
                    assert!(
                        sched.in_use(user) <= 1,
                        "quota exceeded mid-flight"
                    );
                    // Simulate work.
                    sched
                        .hv()
                        .clock
                        .advance(VirtualTime::from_millis_f64(50.0));
                    lease.release().unwrap();
                }
            });
        }
    });
    // Everyone finished; the cluster is empty again.
    assert!(sched.active_grants().is_empty());
    let granted = sched.hv().metrics.counter("sched.granted").get();
    assert_eq!(granted, 24, "8 tenants x 3 jobs all admitted");
    for u in &tenants {
        assert_eq!(sched.usage(*u).granted, 3);
        assert_eq!(sched.usage(*u).released, 3);
        assert!(sched.usage(*u).device_seconds > 0.0);
    }
}

#[test]
fn reservation_expiry_is_reclaimed_for_queued_work() {
    let sched = boot(&ClusterConfig::single_vc707());
    let holder = sched.hv().add_user("holder");
    let worker = sched.hv().add_user("worker");
    let now = sched.hv().clock.now();
    // Reserve the whole device for 100 virtual seconds, never claim.
    sched.reserve(holder, 4, None, now, VirtualTime::from_secs_f64(100.0));
    let ticket = sched.enqueue(&one(
        worker,
        ServiceModel::RAaaS,
        RequestClass::Batch,
    ));
    assert!(
        sched.poll_ticket(ticket).is_none(),
        "withheld while reserved"
    );
    // Let the window lapse; the next admission attempt reaps it.
    sched.hv().clock.advance(VirtualTime::from_secs_f64(200.0));
    let g2 = sched
        .admit(&one(worker, ServiceModel::RAaaS, RequestClass::Normal))
        .unwrap();
    // The queued ticket was pumped in by the same reclamation.
    let first = sched
        .poll_ticket(ticket)
        .expect("queued request admitted after expiry")
        .unwrap();
    first.release().unwrap();
    g2.release().unwrap();
}

// ===================================================== wire auth

/// Every mutating v2 RPC must reject a forged (never-issued) and a
/// stale (released) lease token with the structured `bad_token` code
/// — acting on the honor-system `user` field instead would be the
/// spoofing surface the redesign closes.
#[test]
fn forged_and_stale_tokens_are_rejected_on_every_mutating_rpc() {
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let user = c.add_user("honest").unwrap().user;
    let lease = c.alloc_vfpga(user, None, None).unwrap();
    let alloc = lease.alloc;
    let real_token = lease.lease;

    // Forge a token for the same (live) allocation. The `user` field
    // is the legitimate owner's — exactly the spoofing scenario.
    let forged = LeaseToken(0xDEAD_BEEF);
    assert_ne!(forged, real_token);
    c.set_lease_token(alloc, forged);
    let mutating: Vec<(&str, Box<dyn FnMut(&mut Client) -> ErrorCode>)> = vec![
        (
            "program_core",
            Box::new(move |c: &mut Client| {
                c.program_core(user, alloc, "matmul16").unwrap_err().code
            }),
        ),
        (
            "stream",
            Box::new(move |c: &mut Client| {
                c.stream(user, alloc, "matmul16", 16).unwrap_err().code
            }),
        ),
        (
            "program_full",
            Box::new(move |c: &mut Client| {
                c.program_full(user, alloc, None).unwrap_err().code
            }),
        ),
        (
            "migrate",
            Box::new(move |c: &mut Client| {
                c.migrate(user, alloc).unwrap_err().code
            }),
        ),
        (
            "release",
            Box::new(move |c: &mut Client| {
                c.release(alloc).unwrap_err().code
            }),
        ),
    ];
    for (name, mut call) in mutating {
        assert_eq!(
            call(&mut c),
            ErrorCode::BadToken,
            "{name} accepted a forged token"
        );
    }
    // Omitting the token entirely is also bad_token on v2.
    let mut fresh = Client::connect(server.addr()).unwrap();
    let err = fresh.release(alloc).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadToken);

    // The real token works; afterwards it is stale and the
    // allocation is gone (bad_lease, not silent success).
    c.set_lease_token(alloc, real_token);
    assert!(c.release(alloc).unwrap().released);
    c.set_lease_token(alloc, real_token);
    let err = c.release(alloc).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadLease);

    // Job ownership: a job submitted under one token rejects job_*
    // calls presenting a different one.
    let lease2 = c.alloc_vfpga(user, None, None).unwrap();
    let job = c.program_full(user, lease2.alloc, None).unwrap().job;
    c.set_job_token(job, forged);
    let err = c.job_status(job).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadToken);
    let err = c.job_cancel(job).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadToken);
    c.set_job_token(job, lease2.lease);
    let body = c.job_wait(job, Some(30.0)).unwrap();
    assert!(body.is_terminal());
    c.release(lease2.alloc).unwrap();
}

/// A 4-region gang request over the wire either grants all four
/// members atomically (one lease token, four placements) or queues —
/// the heterogeneous-testbed acceptance scenario.
#[test]
fn wire_gang_grants_all_or_queues() {
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::sched_testbed(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let user = c.add_user("gang").unwrap().user;
    let mut req = rc3e::middleware::api::AllocVfpgaRequest::single(
        user,
        Some(ServiceModel::BAaaS),
        Some(RequestClass::Normal),
    );
    req.regions = Some(4);
    req.co_located = Some(true);
    let resp = c.alloc_vfpga_with(&req).unwrap();
    assert_eq!(resp.members.len(), 4);
    let fpgas: std::collections::BTreeSet<_> =
        resp.members.iter().map(|m| m.fpga).collect();
    assert_eq!(fpgas.len(), 1, "co-located gang split across devices");
    // All four members share the one capability token; releasing by
    // any member tears down the whole gang.
    assert!(c.release(resp.members[2].alloc).unwrap().released);
    assert_eq!(
        server.scheduler().in_use(user),
        0,
        "gang fully released"
    );
    // A second 4-gang immediately after release fits again; a 9-gang
    // can never fit and fails with a structured error.
    let resp2 = c.alloc_vfpga_with(&req).unwrap();
    assert_eq!(resp2.members.len(), 4);
    req.regions = Some(9);
    req.co_located = Some(false);
    let err = c.alloc_vfpga_with(&req).unwrap_err();
    assert!(
        matches!(
            err.code,
            ErrorCode::NoCapacity | ErrorCode::BadRequest
        ),
        "{err}"
    );
}
