//! Scheduler storm: hundreds of tenants contending for a 4-region
//! testbed, plus a preemption-by-migration vignette.
//!
//! Part 1 — 40 tenants × 5 jobs each (200 requests, 50× the region
//! capacity) submit through the cluster scheduler at batch class.
//! Every tenant is capped at 1 concurrent vFPGA and carries a
//! fair-share weight of 1, 2 or 4. The run demonstrates:
//! * bounded wait — every admitted request eventually completes;
//! * quota enforcement — concurrent leases never exceed the cap;
//! * weighted fairness — heavier tenants wait less on average.
//!
//! Part 2 — on the heterogeneous `sched_testbed` (one RAaaS+BAaaS
//! device, one BAaaS-only device), batch leases fill the only
//! RAaaS-capable device; interactive requests then land by migrating
//! batch victims to the BAaaS-only device.
//!
//! Run: `cargo run --release --example scheduler_storm`

use std::sync::Arc;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::sched::{
    AdmissionRequest, Lease, RequestClass, Scheduler, TenantQuota,
};
use rc3e::service::RaaasService;
use rc3e::util::clock::{VirtualClock, VirtualTime};
use rc3e::util::ids::{TicketId, UserId};
use rc3e::util::table::Table;

const TENANTS: usize = 40;
const JOBS_PER_TENANT: usize = 5;
const HOLD_S: f64 = 2.0;

fn boot(config: &ClusterConfig) -> Result<Arc<Scheduler>, String> {
    let hv = Arc::new(
        Hypervisor::boot(
            config,
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .map_err(|e| e.to_string())?,
    );
    Ok(Scheduler::new(hv))
}

fn main() -> Result<(), String> {
    rc3e::util::logging::init();
    storm()?;
    preemption_vignette()?;
    Ok(())
}

fn storm() -> Result<(), String> {
    println!("== Part 1: admission storm on a 4-region testbed ==");
    let sched = boot(&ClusterConfig::single_vc707())?;
    let weights = [1u64, 2, 4];
    let tenants: Vec<(UserId, u64)> = (0..TENANTS)
        .map(|i| {
            let user = sched.hv().add_user(&format!("tenant-{i:02}"));
            let weight = weights[i % weights.len()];
            sched.set_quota(
                user,
                TenantQuota {
                    max_concurrent: 1,
                    weight,
                    ..TenantQuota::default()
                },
            );
            (user, weight)
        })
        .collect();

    // Submit everything up front: 200 requests, 4 regions.
    let mut outstanding: Vec<TicketId> = Vec::new();
    for _ in 0..JOBS_PER_TENANT {
        for (user, _) in &tenants {
            outstanding.push(sched.enqueue(&AdmissionRequest::new(
                *user,
                ServiceModel::RAaaS,
                RequestClass::Batch,
            )));
        }
    }
    let total = outstanding.len();
    println!(
        "submitted {total} requests from {TENANTS} tenants \
         ({}x region capacity)",
        total / 4
    );

    // Drive to completion: hold each granted lease for {HOLD_S}s of
    // virtual time, then release (which pumps the next admission in).
    let mut completed = 0usize;
    let mut quota_violations = 0usize;
    let mut wait_by_weight: Vec<(u64, f64, usize)> =
        weights.iter().map(|w| (*w, 0.0, 0)).collect();
    let mut max_wait_s = 0.0f64;
    while completed < total {
        let mut ready: Vec<Lease> = Vec::new();
        let mut i = 0;
        while i < outstanding.len() {
            match sched.poll_ticket(outstanding[i]) {
                Some(Ok(lease)) => {
                    ready.push(lease);
                    outstanding.remove(i);
                }
                Some(Err(e)) => return Err(format!("request failed: {e}")),
                None => i += 1,
            }
        }
        assert!(
            !ready.is_empty(),
            "liveness: requests outstanding but none admitted"
        );
        for lease in ready {
            if sched.in_use(lease.tenant()) > 1 {
                quota_violations += 1;
            }
            let wait_s = lease.wait().as_secs_f64();
            max_wait_s = max_wait_s.max(wait_s);
            let weight = sched.quota(lease.tenant()).weight;
            if let Some(row) =
                wait_by_weight.iter_mut().find(|(w, _, _)| *w == weight)
            {
                row.1 += wait_s;
                row.2 += 1;
            }
            // Simulated work.
            sched
                .hv()
                .clock
                .advance(VirtualTime::from_secs_f64(HOLD_S));
            lease.release().map_err(|e| e.to_string())?;
            completed += 1;
        }
    }

    let mut table = Table::new(
        "Admission waits by fair-share weight",
        &["weight", "requests", "mean wait s", "ideal share"],
    );
    for (weight, total_wait, n) in &wait_by_weight {
        table.row(&[
            format!("{weight}"),
            format!("{n}"),
            format!("{:.1}", total_wait / (*n).max(1) as f64),
            format!(
                "{:.0}%",
                *weight as f64 * 100.0
                    / (weights.iter().sum::<u64>() as f64)
            ),
        ]);
    }
    print!("{}", table.render());
    println!(
        "completed {completed}/{total}; quota violations: \
         {quota_violations}; max wait {max_wait_s:.1} s (virtual)"
    );
    assert_eq!(quota_violations, 0, "per-tenant quota must hold");
    assert!(outstanding.is_empty(), "no request may starve");
    println!();
    Ok(())
}

fn preemption_vignette() -> Result<(), String> {
    println!("== Part 2: interactive preemption via migration ==");
    let sched = boot(&ClusterConfig::sched_testbed())?;
    let raaas = RaaasService::with_scheduler(Arc::clone(&sched));
    let batcher = sched.hv().add_user("batcher");

    // Fill the only RAaaS-capable device with programmed batch work.
    rc3e::testing::fill_batch_leases(&sched, batcher, 4);
    println!("4 batch leases programmed on the RAaaS-capable device");

    // Two interactive tenants arrive on the full device: each lease
    // relocates one batch victim to the BAaaS-only device.
    for name in ["vip-1", "vip-2"] {
        let vip = sched.hv().add_user(name);
        let lease = raaas.alloc(vip).map_err(|e| e.to_string())?;
        let vfpga = lease.vfpga().ok_or("interactive lease unplaced")?;
        println!(
            "{name}: landed on {vfpga} after preempting a batch lease \
             (migrations so far: {})",
            sched.hv().metrics.counter("hv.migrations").get()
        );
        // Keep the lease live for the usage report below.
        let _token = lease.into_token();
    }
    let preemptions = sched.hv().metrics.counter("sched.preemptions").get();
    assert_eq!(preemptions, 2, "both interactive leases preempted");

    // Release everything and show the bill.
    for grant in sched.active_grants() {
        sched
            .hv()
            .clock
            .advance(VirtualTime::from_secs_f64(1.0));
        sched.release(grant.alloc).map_err(|e| e.to_string())?;
    }
    print!("{}", sched.usage_report());
    println!(
        "batcher was preempted {} times; all leases settled",
        sched.usage(batcher).preempted
    );
    Ok(())
}
