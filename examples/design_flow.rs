//! RSaaS design-exploration: the cloud as a hardware-development
//! platform (Section III-A).
//!
//! A hardware developer leases a VM with a full FPGA passed through,
//! runs several HLS design-flow variants *in parallel* (the paper:
//! "The ability to run multiple design flows simultaneously can
//! greatly reduce design exploration time"), picks the best core by
//! synthesis report, writes a full bitstream to the device, and
//! finally returns everything to the cloud.
//!
//! Run: `cargo run --release --example design_flow`

use std::sync::Arc;

use rc3e::config::ClusterConfig;
use rc3e::fpga::RegionShape;
use rc3e::hls::{CoreSpec, DesignFlow};
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::util::clock::VirtualClock;
use rc3e::util::table::Table;
use rc3e::vm::VmManager;

fn main() -> Result<(), String> {
    rc3e::util::logging::init();
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            Arc::clone(&clock),
            PlacementPolicy::ConsolidateFirst,
        )
        .map_err(|e| e.to_string())?,
    );

    // Lease a development VM with the FPGA passed through.
    let vms = VmManager::new(Arc::clone(&hv));
    let user = hv.add_user("hwdev");
    let vm = vms.launch(user, 8, 16).map_err(|e| e.to_string())?;
    println!(
        "dev VM {} running with {} passed through (boot {:.0} s virtual)",
        vm.id,
        vm.fpga,
        rc3e::vm::VM_BOOT_S
    );

    // Explore matmul sizes in parallel design flows. Each flow
    // charges ~23 min of virtual build time; running them on parallel
    // "build machines" means the clocks overlap (advance_max), so the
    // exploration finishes in one flow's time, not four.
    let quarter = {
        let dev = hv.device(vm.fpga).map_err(|e| e.to_string())?;
        let hw = dev.fpga.lock().unwrap();
        hw.regions()
            .first()
            .map(|r| r.capacity)
            .unwrap_or(rc3e::fpga::Resources::new(59_000, 118_000, 200, 560))
    };
    let t0 = clock.now();
    let results: Vec<_> = std::thread::scope(|scope| {
        [8usize, 16, 24, 32]
            .map(|n| {
                let clock = Arc::clone(&clock);
                scope.spawn(move || {
                    let flow = DesignFlow::new(clock);
                    let spec = CoreSpec::matmul(n, "xc7vx485t");
                    (
                        n,
                        flow.run(
                            &spec,
                            RegionShape::Quarter,
                            0,
                            64,
                            quarter,
                        ),
                    )
                })
            })
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    println!(
        "4 parallel design flows finished in {:.0} min virtual \
         (sequential would be ~{:.0} min)",
        clock.since(t0).as_secs_f64() / 60.0,
        4.0 * 23.0
    );

    let mut table = Table::new(
        "Design exploration: streaming matmul variants (quarter region)",
        &["core", "LUT", "FF", "DSP", "rate", "fits?"],
    );
    let mut best: Option<(usize, f64)> = None;
    for (n, result) in &results {
        match result {
            Ok(out) => {
                let r = &out.report;
                let total = r.total_for(1);
                table.row(&[
                    format!("matmul{n}"),
                    total.lut.to_string(),
                    total.ff.to_string(),
                    total.dsp.to_string(),
                    format!("{:.0} MB/s", r.rate_mbps),
                    "yes".to_string(),
                ]);
                if best.map(|(_, rate)| r.rate_mbps > rate).unwrap_or(true) {
                    best = Some((*n, r.rate_mbps));
                }
            }
            Err(e) => {
                table.row(&[
                    format!("matmul{n}"),
                    "—".into(),
                    "—".into(),
                    "—".into(),
                    "—".into(),
                    format!("no ({e})"),
                ]);
            }
        }
    }
    println!("{}", table.render());
    let (best_n, best_rate) = best.ok_or("no variant fit")?;
    println!("selected matmul{best_n} ({best_rate:.0} MB/s)");

    // RSaaS privilege: write a FULL bitstream to the passed-through
    // device (with PCIe hot-plug handling).
    let full = rc3e::bitstream::BitstreamBuilder::full(
        "xc7vx485t",
        &format!("hwdev_matmul{best_n}_standalone"),
    )
    .build();
    let alloc = vm.allocation;
    let d = hv.program_full(alloc, user, &full).map_err(|e| e.to_string())?;
    println!(
        "full bitstream written in {:.2} s (paper: 29.5 s over RC3E)",
        d.as_secs_f64()
    );

    // Tear down: VM destroyed, FPGA back in the pool.
    vms.destroy(vm.id).map_err(|e| e.to_string())?;
    println!("VM destroyed; device returned to the cloud");
    Ok(())
}
