//! Multi-tenant contention: the paper's Section-V experiment as an
//! example.
//!
//! Four users lease the four vFPGAs of one physical VC707 and stream
//! simultaneously. With one active core the stream is compute-bound
//! (≈509 MB/s); as tenants join, the shared 800 MB/s PCIe link
//! becomes the bottleneck and per-core throughput falls to ≈398 then
//! ≈198 MB/s — while *aggregate* device throughput and utilization
//! rise, which is the paper's argument for vFPGA consolidation.
//!
//! Run: `cargo run --release --example multi_tenant`

use std::sync::Arc;

use rc3e::config::ClusterConfig;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::rc2f::{StreamConfig, StreamRunner};
use rc3e::service::RaaasService;
use rc3e::util::clock::VirtualClock;
use rc3e::util::table::Table;

fn main() -> Result<(), String> {
    rc3e::util::logging::init();
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            Arc::clone(&clock),
            PlacementPolicy::ConsolidateFirst,
        )
        .map_err(|e| e.to_string())?,
    );
    let svc = RaaasService::new(Arc::clone(&hv));

    // Four tenants, four leases, all on the same physical device
    // (consolidate-first packs them).
    let synth = rc3e::hls::Synthesizer::new();
    let report =
        synth.synthesize(&rc3e::hls::CoreSpec::matmul(16, "xc7vx485t"));
    let mut leases = Vec::new();
    for name in ["alice", "bob", "carol", "dave"] {
        let user = hv.add_user(name);
        let lease = svc.alloc(user).map_err(|e| e.to_string())?;
        let vfpga = lease.vfpga().ok_or("fresh lease unplaced")?;
        let bitfile = rc3e::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "matmul16",
        )
        .resources(report.total_for(1))
        .frames(rc3e::hls::flow::region_window(0, 1))
        .artifact("matmul16_b256")
        .build();
        lease.program(&bitfile).map_err(|e| e.to_string())?;
        println!("{name}: programmed matmul16 on {vfpga}");
        leases.push(lease);
    }

    const MULTS: u64 = 20_000;
    let mut table = Table::new(
        "Per-core throughput vs active tenants (16x16, paper Table III)",
        &[
            "tenants",
            "modeled/core",
            "paper",
            "aggregate",
            "wall/core (host)",
        ],
    );
    let paper = [509.0, 398.0, 0.0, 198.0];

    let fpga = hv.device_ids()[0];
    let link = Arc::clone(&hv.device(fpga).map_err(|e| e.to_string())?.link);
    for tenants in [1usize, 2, 4] {
        let runner =
            StreamRunner::new(Arc::clone(&clock), Arc::clone(&link));
        let cfgs: Vec<StreamConfig> = (0..tenants)
            .map(|i| StreamConfig {
                seed: 0x100 + i as u64,
                ..StreamConfig::matmul16(MULTS)
            })
            .collect();
        let outs = runner.run_concurrent(&cfgs)?;
        let per_core: f64 = outs.iter().map(|o| o.virtual_mbps()).sum::<f64>()
            / tenants as f64;
        let wall: f64 = outs.iter().map(|o| o.wall_mbps()).sum::<f64>()
            / tenants as f64;
        for o in &outs {
            assert_eq!(o.validation_failures, 0, "numerics diverged");
        }
        table.row(&[
            tenants.to_string(),
            format!("{per_core:.0} MB/s"),
            if paper[tenants - 1] > 0.0 {
                format!("{:.0} MB/s", paper[tenants - 1])
            } else {
                "—".to_string()
            },
            format!("{:.0} MB/s", per_core * tenants as f64),
            format!("{wall:.0} MB/s"),
        ]);
    }
    println!("\n{}", table.render());
    println!(
        "aggregate rises with tenants even as each core slows — the \
         utilization argument for vFPGAs (Section V)."
    );

    for lease in leases {
        lease.release().map_err(|e| e.to_string())?;
    }
    Ok(())
}
