//! END-TO-END driver: the full RC3E system on a real workload.
//!
//! Boots the paper's two-node testbed (4 FPGAs / 16 vFPGAs), brings
//! up the *real* middleware — management server + one node agent per
//! node, all over TCP — and then runs a mixed multi-user workload
//! through the public surfaces only:
//!
//! 1. CLI-equivalent RPC path: add users, lease vFPGAs, program
//!    cores, stream (Fig. 3's interaction), migrate a live design;
//! 2. BAaaS background service invocations;
//! 3. the Section-V experiment at full scale: 100,000 matrix
//!    multiplications per core with 1/2/4 concurrent cores (16×16)
//!    and 1/2 cores (32×32), reporting modeled runtime + throughput
//!    against the paper's Table III, plus wall-clock numbers for the
//!    real PJRT compute on this host;
//! 4. energy accounting across the run.
//!
//! Run: `cargo run --release --example e2e_cloud`
//! (Set RC3E_E2E_MULTS to override the 100,000-mult full scale.)

use std::sync::Arc;

use rc3e::hypervisor::Hypervisor;
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::rc2f::{StreamConfig, StreamRunner};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::NodeId;
use rc3e::util::json::Json;
use rc3e::util::table::Table;

fn main() -> Result<(), String> {
    rc3e::util::logging::init();
    let mults: u64 = std::env::var("RC3E_E2E_MULTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(rc3e::paper::STREAM_MULTS);

    // ---------------- boot the cloud + middleware ------------------
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock))
            .map_err(|e| e.to_string())?,
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0)
        .map_err(|e| e.to_string())?;
    let mut agents = Vec::new();
    for node in [NodeId(0), NodeId(1)] {
        let agent = NodeAgent::spawn(Arc::clone(&hv), node, None)
            .map_err(|e| e.to_string())?;
        server.register_agent(node, agent.addr());
        agents.push(agent);
    }
    println!(
        "cloud: 2 nodes / 4 FPGAs / 16 vFPGAs; management at {}; \
         virtual boot {:.1} s",
        server.addr(),
        clock.now().as_secs_f64()
    );

    // ---------------- 1. interactive RAaaS path over TCP -----------
    let mut cli = Client::connect(server.addr())?;
    let user = cli
        .call("add_user", Json::obj(vec![("name", Json::from("alice"))]))?
        .get("user")
        .as_str()
        .unwrap()
        .to_string();
    let lease = cli.call(
        "alloc_vfpga",
        Json::obj(vec![("user", Json::from(user.as_str()))]),
    )?;
    let alloc = lease.get("alloc").as_str().unwrap().to_string();
    println!(
        "alice leased {} on {} ({})",
        lease.get("vfpga").as_str().unwrap(),
        lease.get("fpga").as_str().unwrap(),
        lease.get("node").as_str().unwrap()
    );
    let prog = cli.call(
        "program_core",
        Json::obj(vec![
            ("user", Json::from(user.as_str())),
            ("alloc", Json::from(alloc.as_str())),
            ("core", Json::from("matmul16")),
        ]),
    )?;
    println!(
        "programmed matmul16 over RC3E in {:.0} ms (paper PR row: 912 ms)",
        prog.get("pr_ms").as_f64().unwrap() + 69.0
    );
    let st = cli.call(
        "status",
        Json::obj(vec![(
            "fpga",
            Json::from(lease.get("fpga").as_str().unwrap()),
        )]),
    )?;
    println!(
        "status via node agent: {} regions, {} configured, {:.1} W",
        st.get("regions_total").as_u64().unwrap(),
        st.get("regions_configured").as_u64().unwrap(),
        st.get("power_w").as_f64().unwrap()
    );
    let small = cli.call(
        "stream",
        Json::obj(vec![
            ("user", Json::from(user.as_str())),
            ("alloc", Json::from(alloc.as_str())),
            ("core", Json::from("matmul16")),
            ("mults", Json::from(10_000u64)),
        ]),
    )?;
    assert_eq!(small.get("validation_failures").as_u64(), Some(0));
    println!(
        "alice streamed 10k mults: modeled {:.0} MB/s, wall {:.0} MB/s",
        small.get("virtual_mbps").as_f64().unwrap(),
        small.get("wall_mbps").as_f64().unwrap()
    );
    // Live migration of alice's design.
    let mig = cli.call(
        "migrate",
        Json::obj(vec![
            ("user", Json::from(user.as_str())),
            ("alloc", Json::from(alloc.as_str())),
        ]),
    )?;
    println!(
        "migrated {} -> {} (cross-device: {}, downtime {:.0} ms)",
        mig.get("from").as_str().unwrap(),
        mig.get("to").as_str().unwrap(),
        mig.get("cross_device").as_bool().unwrap(),
        mig.get("downtime_ms").as_f64().unwrap()
    );
    cli.call(
        "release",
        Json::obj(vec![("alloc", Json::from(alloc.as_str()))]),
    )?;

    // ---------------- 2. BAaaS background service ------------------
    let synth = rc3e::hls::Synthesizer::new();
    let report16 =
        synth.synthesize(&rc3e::hls::CoreSpec::matmul(16, "xc7vx485t"));
    hv.register_service(
        "linalg",
        rc3e::bitstream::BitstreamBuilder::partial("xc7vx485t", "matmul16")
            .resources(report16.total_for(1))
            .frames(rc3e::hls::flow::region_window(0, 1))
            .artifact("matmul16_b256")
            .build(),
    );
    let enduser = cli
        .call("add_user", Json::obj(vec![("name", Json::from("bob"))]))?
        .get("user")
        .as_str()
        .unwrap()
        .to_string();
    let svc_out = cli.call(
        "invoke_service",
        Json::obj(vec![
            ("user", Json::from(enduser.as_str())),
            ("service", Json::from("linalg")),
            ("mults", Json::from(10_000u64)),
        ]),
    )?;
    println!(
        "bob invoked BAaaS 'linalg' (no FPGA visible): {:.0} MB/s modeled",
        svc_out.get("virtual_mbps").as_f64().unwrap()
    );

    // ---------------- 3. Section-V experiment at full scale --------
    println!("\nSection V experiment: {mults} multiplications per core");
    let fpga = hv.device_ids()[0];
    let link = Arc::clone(&hv.device(fpga).map_err(|e| e.to_string())?.link);
    let mut table = Table::new(
        "Table III reproduction (streaming matmul, 32-bit float)",
        &[
            "design",
            "cores",
            "runtime/core",
            "paper",
            "MB/s per core",
            "paper",
            "wall/core (host)",
        ],
    );
    let cases: Vec<(usize, usize, f64, f64)> = vec![
        (16, 1, 0.73, 509.0),
        (16, 2, 0.86, 398.0),
        (16, 4, 1.41, 198.0),
        (32, 1, 3.27, 279.0),
        (32, 2, 3.43, 277.0),
    ];
    for (n, cores, paper_rt, paper_tp) in cases {
        let runner = StreamRunner::new(Arc::clone(&clock), Arc::clone(&link));
        let cfgs: Vec<StreamConfig> = (0..cores)
            .map(|i| {
                let base = if n == 16 {
                    StreamConfig::matmul16(mults)
                } else {
                    StreamConfig::matmul32(mults)
                };
                StreamConfig {
                    seed: 0xE2E + i as u64,
                    validate_first_chunk: i == 0,
                    ..base
                }
            })
            .collect();
        let outs = runner.run_concurrent(&cfgs)?;
        for o in &outs {
            assert_eq!(
                o.validation_failures, 0,
                "numerics diverged on {n}x{n}"
            );
        }
        let rt = outs
            .iter()
            .map(|o| o.virtual_total.as_secs_f64())
            .sum::<f64>()
            / cores as f64;
        let tp = outs.iter().map(|o| o.virtual_mbps()).sum::<f64>()
            / cores as f64;
        let wall = outs.iter().map(|o| o.wall_mbps()).sum::<f64>()
            / cores as f64;
        table.row(&[
            format!("{n}x{n}"),
            cores.to_string(),
            format!("{rt:.2} s"),
            format!("{paper_rt:.2} s"),
            format!("{tp:.0}"),
            format!("{paper_tp:.0}"),
            format!("{wall:.0} MB/s"),
        ]);
    }
    println!("{}", table.render());

    // ---------------- 4. energy accounting -------------------------
    let energy = cli.call("energy", Json::obj(vec![]))?;
    println!(
        "cloud energy over the run: {:.0} J virtual, final draw {:.1} W",
        energy.get("joules").as_f64().unwrap(),
        energy.get("power_w").as_f64().unwrap()
    );
    println!("\nE2E OK — all layers composed (TCP middleware, hypervisor, \
              RC2F streaming, PJRT compute).");
    Ok(())
}
