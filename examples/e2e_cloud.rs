//! END-TO-END driver: the full RC3E system on a real workload.
//!
//! Boots the paper's two-node testbed (4 FPGAs / 16 vFPGAs), brings
//! up the *real* middleware — management server + one node agent per
//! node, all over TCP — and then runs a mixed multi-user workload
//! through the public surfaces only:
//!
//! 1. CLI-equivalent RPC path: add users, lease vFPGAs, program
//!    cores, stream (Fig. 3's interaction), migrate a live design;
//! 2. BAaaS background service invocations;
//! 3. the Section-V experiment at full scale: 100,000 matrix
//!    multiplications per core with 1/2/4 concurrent cores (16×16)
//!    and 1/2 cores (32×32), reporting modeled runtime + throughput
//!    against the paper's Table III, plus wall-clock numbers for the
//!    real PJRT compute on this host;
//! 4. energy accounting across the run.
//!
//! Run: `cargo run --release --example e2e_cloud`
//! (Set RC3E_E2E_MULTS to override the 100,000-mult full scale.)

use std::sync::Arc;

use rc3e::hypervisor::Hypervisor;
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::rc2f::{StreamConfig, StreamRunner};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::NodeId;
use rc3e::util::table::Table;

fn main() -> Result<(), String> {
    rc3e::util::logging::init();
    let mults: u64 = std::env::var("RC3E_E2E_MULTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(rc3e::paper::STREAM_MULTS);

    // ---------------- boot the cloud + middleware ------------------
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock))
            .map_err(|e| e.to_string())?,
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0)
        .map_err(|e| e.to_string())?;
    let mut agents = Vec::new();
    for node in [NodeId(0), NodeId(1)] {
        let agent = NodeAgent::spawn(Arc::clone(&hv), node, None)
            .map_err(|e| e.to_string())?;
        server.register_agent(node, agent.addr());
        agents.push(agent);
    }
    println!(
        "cloud: 2 nodes / 4 FPGAs / 16 vFPGAs; management at {}; \
         virtual boot {:.1} s",
        server.addr(),
        clock.now().as_secs_f64()
    );

    // ---------------- 1. interactive RAaaS path over TCP -----------
    let mut cli = Client::connect(server.addr())?;
    let user = cli
        .add_user("alice")
        .map_err(|e| e.to_string())?
        .user;
    let lease =
        cli.alloc_vfpga(user, None, None).map_err(|e| e.to_string())?;
    let alloc = lease.alloc;
    println!(
        "alice leased {} on {} ({}); capability token {}",
        lease.vfpga, lease.fpga, lease.node, lease.lease
    );
    let prog = cli
        .program_core(user, alloc, "matmul16")
        .map_err(|e| e.to_string())?;
    println!(
        "programmed matmul16 over RC3E in {:.0} ms (paper PR row: 912 ms)",
        prog.pr_ms + 69.0
    );
    let st = cli.status(lease.fpga).map_err(|e| e.to_string())?;
    println!(
        "status via node agent: {} regions, {} configured, {:.1} W",
        st.regions_total, st.regions_configured, st.power_w
    );
    let small = cli
        .stream_sync(user, alloc, "matmul16", 10_000)
        .map_err(|e| e.to_string())?;
    assert_eq!(small.validation_failures, 0);
    println!(
        "alice streamed 10k mults: modeled {:.0} MB/s, wall {:.0} MB/s",
        small.virtual_mbps, small.wall_mbps
    );
    // Live migration of alice's design.
    let mig = cli.migrate(user, alloc).map_err(|e| e.to_string())?;
    println!(
        "migrated {} -> {} (cross-device: {}, downtime {:.0} ms)",
        mig.from, mig.to, mig.cross_device, mig.downtime_ms
    );
    cli.release(alloc).map_err(|e| e.to_string())?;

    // ---------------- 2. BAaaS background service ------------------
    let synth = rc3e::hls::Synthesizer::new();
    let report16 =
        synth.synthesize(&rc3e::hls::CoreSpec::matmul(16, "xc7vx485t"));
    hv.register_service(
        "linalg",
        rc3e::bitstream::BitstreamBuilder::partial("xc7vx485t", "matmul16")
            .resources(report16.total_for(1))
            .frames(rc3e::hls::flow::region_window(0, 1))
            .artifact("matmul16_b256")
            .build(),
    );
    let enduser =
        cli.add_user("bob").map_err(|e| e.to_string())?.user;
    let svc_out = cli
        .invoke_service_sync(enduser, "linalg", 10_000)
        .map_err(|e| e.to_string())?;
    println!(
        "bob invoked BAaaS 'linalg' (no FPGA visible): {:.0} MB/s modeled",
        svc_out.virtual_mbps
    );

    // ---------------- 3. Section-V experiment at full scale --------
    println!("\nSection V experiment: {mults} multiplications per core");
    let fpga = hv.device_ids()[0];
    let link = Arc::clone(&hv.device(fpga).map_err(|e| e.to_string())?.link);
    let mut table = Table::new(
        "Table III reproduction (streaming matmul, 32-bit float)",
        &[
            "design",
            "cores",
            "runtime/core",
            "paper",
            "MB/s per core",
            "paper",
            "wall/core (host)",
        ],
    );
    let cases: Vec<(usize, usize, f64, f64)> = vec![
        (16, 1, 0.73, 509.0),
        (16, 2, 0.86, 398.0),
        (16, 4, 1.41, 198.0),
        (32, 1, 3.27, 279.0),
        (32, 2, 3.43, 277.0),
    ];
    for (n, cores, paper_rt, paper_tp) in cases {
        let runner = StreamRunner::new(Arc::clone(&clock), Arc::clone(&link));
        let cfgs: Vec<StreamConfig> = (0..cores)
            .map(|i| {
                let base = if n == 16 {
                    StreamConfig::matmul16(mults)
                } else {
                    StreamConfig::matmul32(mults)
                };
                StreamConfig {
                    seed: 0xE2E + i as u64,
                    validate_first_chunk: i == 0,
                    ..base
                }
            })
            .collect();
        let outs = runner.run_concurrent(&cfgs)?;
        for o in &outs {
            assert_eq!(
                o.validation_failures, 0,
                "numerics diverged on {n}x{n}"
            );
        }
        let rt = outs
            .iter()
            .map(|o| o.virtual_total.as_secs_f64())
            .sum::<f64>()
            / cores as f64;
        let tp = outs.iter().map(|o| o.virtual_mbps()).sum::<f64>()
            / cores as f64;
        let wall = outs.iter().map(|o| o.wall_mbps()).sum::<f64>()
            / cores as f64;
        table.row(&[
            format!("{n}x{n}"),
            cores.to_string(),
            format!("{rt:.2} s"),
            format!("{paper_rt:.2} s"),
            format!("{tp:.0}"),
            format!("{paper_tp:.0}"),
            format!("{wall:.0} MB/s"),
        ]);
    }
    println!("{}", table.render());

    // ---------------- 4. energy accounting -------------------------
    let energy = cli.energy().map_err(|e| e.to_string())?;
    println!(
        "cloud energy over the run: {:.0} J virtual, final draw {:.1} W",
        energy.joules, energy.power_w
    );
    println!("\nE2E OK — all layers composed (TCP middleware, hypervisor, \
              RC2F streaming, PJRT compute).");
    Ok(())
}
