// Perf probe: wall-time composition of one stream.
use std::sync::Arc;
use rc3e::pcie::{DeviceLink, LinkParams};
use rc3e::rc2f::{StreamConfig, StreamRunner};
use rc3e::util::clock::VirtualClock;

fn main() {
    rc3e::util::logging::init();
    for (name, cfg) in [
        ("16x16", StreamConfig { validate_first_chunk: false, ..StreamConfig::matmul16(50_000) }),
        ("16x16+val", StreamConfig::matmul16(50_000)),
        ("32x32", StreamConfig { validate_first_chunk: false, ..StreamConfig::matmul32(20_000) }),
    ] {
        let clock = VirtualClock::new();
        let link = DeviceLink::new(Arc::clone(&clock), LinkParams::gen2_x4());
        let runner = StreamRunner::new(clock, link);
        let out = runner.run(&cfg).unwrap();
        println!(
            "{name}: wall {:.3}s compute {:.3}s ({:.0}%) -> {:.0} MB/s wall",
            out.wall_secs, out.compute_wall_secs,
            100.0 * out.compute_wall_secs / out.wall_secs,
            out.wall_mbps()
        );
    }
}
