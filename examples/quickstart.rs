//! Quickstart: the RAaaS "hello world".
//!
//! Boots a single-node cloud, leases one vFPGA, programs the 16×16
//! streaming matmul core (HLS flow → relocatable partial bitstream →
//! sanity-checked PR) and streams matrices through it — real data,
//! real PJRT compute, virtual hardware timing.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use rc3e::config::ClusterConfig;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::rc2f::StreamConfig;
use rc3e::service::RaaasService;
use rc3e::util::clock::VirtualClock;

fn main() -> Result<(), String> {
    rc3e::util::logging::init();

    // 1. Boot the cloud (one VC707; the RC2F basic design is loaded
    //    per device, charging the 28.37 s JTAG configuration to the
    //    virtual clock).
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            Arc::clone(&clock),
            PlacementPolicy::ConsolidateFirst,
        )
        .map_err(|e| e.to_string())?,
    );
    println!(
        "cloud up after {:.2} s virtual boot ({} devices)",
        clock.now().as_secs_f64(),
        hv.device_ids().len()
    );

    // 2. Lease a vFPGA under RAaaS.
    let svc = RaaasService::new(Arc::clone(&hv));
    let user = hv.add_user("quickstart");
    let lease = svc.alloc(user).map_err(|e| e.to_string())?;
    let vfpga = lease.vfpga().ok_or("fresh lease unplaced")?;
    println!(
        "leased {vfpga} (allocation {}, token {})",
        lease.alloc(),
        lease.token()
    );

    // 3. "HLS flow": synthesize the matmul core and build the
    //    relocatable partial bitfile bound to the HLO artifact.
    let synth = rc3e::hls::Synthesizer::new();
    let report =
        synth.synthesize(&rc3e::hls::CoreSpec::matmul(16, "xc7vx485t"));
    println!(
        "synthesized matmul16: {} (rate {:.0} MB/s)",
        report.total_for(1),
        report.rate_mbps
    );
    let bitfile =
        rc3e::bitstream::BitstreamBuilder::partial("xc7vx485t", "matmul16")
            .resources(report.total_for(1))
            .frames(rc3e::hls::flow::region_window(0, 1))
            .artifact("matmul16_b256")
            .build();

    // 4. Program (sanity check → PR → controller update).
    let t0 = clock.now();
    lease.program(&bitfile).map_err(|e| e.to_string())?;
    println!(
        "programmed in {:.0} ms (PR + RC3E orchestration)",
        clock.since(t0).as_millis_f64()
    );

    // 5. Stream 20,000 multiplications through the core.
    let out = lease
        .stream(&StreamConfig::matmul16(20_000))
        .map_err(|e| e.to_string())?;
    println!(
        "streamed {} mults:\n  modeled  {:.3} s → {:.0} MB/s per core \
         (paper: 509 MB/s)\n  wall     {:.3} s → {:.0} MB/s on this host\n  \
         checksum {:.6e}, validation failures: {}",
        out.mults,
        out.virtual_stream.as_secs_f64(),
        out.virtual_mbps(),
        out.wall_secs,
        out.wall_mbps(),
        out.checksum,
        out.validation_failures
    );

    // 6. Release the lease (region blanked, clock gated, files gone).
    lease.release().map_err(|e| e.to_string())?;
    println!("released {vfpga}; device idle power: {:.1} W", hv.total_power_w());
    Ok(())
}
