//! BAaaS + batch system: background acceleration for end users.
//!
//! The provider registers two accelerated services (matmul16 as
//! "linalg-small", matmul32 as "linalg-large"). End users never see
//! FPGAs — they submit jobs against service names; the batch system
//! allocates vFPGAs in the background, retargets the provider
//! bitfiles to wherever placement lands, streams, and releases.
//!
//! Run: `cargo run --release --example batch_baas`

use std::sync::Arc;

use rc3e::batch::{BatchSystem, JobPayload, JobSpec};
use rc3e::hypervisor::Hypervisor;
use rc3e::rc2f::StreamConfig;
use rc3e::util::clock::VirtualClock;

fn provider_bitfile(n: usize, artifact: &str) -> rc3e::bitstream::Bitstream {
    let synth = rc3e::hls::Synthesizer::new();
    let report =
        synth.synthesize(&rc3e::hls::CoreSpec::matmul(n, "xc7vx485t"));
    rc3e::bitstream::BitstreamBuilder::partial(
        "xc7vx485t",
        &format!("matmul{n}"),
    )
    .resources(report.total_for(1))
    .frames(rc3e::hls::flow::region_window(0, 1))
    .artifact(artifact)
    .signed_with("rc3e-provider")
    .build()
}

fn main() -> Result<(), String> {
    rc3e::util::logging::init();
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock))
            .map_err(|e| e.to_string())?,
    );

    // Provider side: register the service catalogue.
    hv.register_service("linalg-small", provider_bitfile(16, "matmul16_b256"));
    println!("provider registered services: {:?}", hv.service_names());

    // End-user side: submit a batch of jobs by service name only.
    let batch = BatchSystem::new(Arc::clone(&hv));
    let mut jobs = Vec::new();
    for i in 0..6 {
        let user = hv.add_user(&format!("enduser-{i}"));
        let id = batch.submit(JobSpec {
            user,
            payload: JobPayload::Service("linalg-small".to_string()),
            stream: StreamConfig {
                seed: 0x9000 + i,
                ..StreamConfig::matmul16(8_000)
            },
        });
        jobs.push(id);
    }
    println!("submitted {} background jobs", jobs.len());

    // Drain with two scheduler workers (two devices' worth of
    // parallelism).
    let t0 = clock.now();
    batch.drain_with_workers(2);
    println!(
        "queue drained in {:.2} s virtual time",
        clock.since(t0).as_secs_f64()
    );

    let mut done = 0;
    for id in jobs {
        match batch.state(id) {
            Some(rc3e::batch::JobState::Done(out)) => {
                done += 1;
                println!(
                    "  {id}: {} mults, modeled {:.0} MB/s, checksum ok={}",
                    out.mults,
                    out.virtual_mbps(),
                    out.validation_failures == 0
                );
            }
            st => println!("  {id}: {:?}", st.map(|s| s.name().to_string())),
        }
    }
    assert_eq!(done, 6, "all jobs must complete");

    // All leases returned; the cloud is idle again.
    println!(
        "idle power {:.1} W, energy so far {:.0} J (virtual)",
        hv.total_power_w(),
        hv.total_energy_joules()
    );
    Ok(())
}
