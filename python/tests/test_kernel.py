"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer: everything
the Rust runtime executes was lowered from these kernels, so agreement
with `ref.py` here transfers to the request path.

Hypothesis sweeps shapes, groups and value distributions; fixed tests
pin the exact geometries the AOT variants ship.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_stream as k
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(
        rng.standard_normal(shape, dtype=np.float32) * scale
    )


# ---------------------------------------------------------------------------
# Fixed geometries: exactly what the AOT variants ship.
# ---------------------------------------------------------------------------

AOT_GEOMETRIES = [
    (256, 16, 8),  # matmul16_b256
    (64, 16, 8),  # matmul16_b64
    (64, 32, 8),  # matmul32_b64
    (16, 32, 8),  # matmul32_b16
]


@pytest.mark.parametrize("batch,n,group", AOT_GEOMETRIES)
def test_matmul_aot_geometry(batch, n, group):
    rng = np.random.default_rng(42)
    xs, ys = _rand(rng, (batch, n, n)), _rand(rng, (batch, n, n))
    out = k.matmul_stream(xs, ys, group=group)
    np.testing.assert_allclose(
        out, ref.matmul_stream_ref(xs, ys), rtol=1e-5, atol=1e-5
    )


def test_matmul_identity():
    """A @ I == A for every matrix in the stream."""
    rng = np.random.default_rng(0)
    xs = _rand(rng, (32, 16, 16))
    eye = jnp.broadcast_to(jnp.eye(16, dtype=jnp.float32), (32, 16, 16))
    np.testing.assert_allclose(
        k.matmul_stream(xs, eye, group=8), xs, rtol=1e-6
    )


def test_matmul_zeros():
    xs = jnp.zeros((16, 16, 16), jnp.float32)
    ys = jnp.ones((16, 16, 16), jnp.float32)
    assert np.all(np.asarray(k.matmul_stream(xs, ys, group=8)) == 0.0)


def test_matmul_batch_independence():
    """Each stream element is multiplied only with its partner."""
    rng = np.random.default_rng(7)
    xs, ys = _rand(rng, (8, 16, 16)), _rand(rng, (8, 16, 16))
    full = np.asarray(k.matmul_stream(xs, ys, group=8))
    for i in range(8):
        np.testing.assert_allclose(
            full[i], np.asarray(xs[i]) @ np.asarray(ys[i]), rtol=1e-5,
            atol=1e-5,
        )


def test_matmul_rejects_nondivisible_batch():
    xs = jnp.zeros((10, 16, 16), jnp.float32)
    with pytest.raises(ValueError, match="not divisible"):
        k.matmul_stream(xs, xs, group=8)


def test_matmul_group_invariance():
    """Group (VMEM packing factor) must not change the numerics."""
    rng = np.random.default_rng(3)
    xs, ys = _rand(rng, (32, 16, 16)), _rand(rng, (32, 16, 16))
    a = k.matmul_stream(xs, ys, group=4)
    b = k.matmul_stream(xs, ys, group=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, dtypes-on-input, scales, degenerate values.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16, 32]),
    groups=st.integers(min_value=1, max_value=4),
    group=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_matmul_hypothesis(n, groups, group, seed, scale):
    batch = groups * group
    rng = np.random.default_rng(seed)
    xs = _rand(rng, (batch, n, n), scale)
    ys = _rand(rng, (batch, n, n), scale)
    out = k.matmul_stream(xs, ys, group=group)
    np.testing.assert_allclose(
        out,
        ref.matmul_stream_ref(xs, ys),
        rtol=1e-4,
        atol=1e-4 * scale * scale * n,
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 16]),
    batch=st.sampled_from([8, 24]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_loopback_hypothesis(n, batch, seed):
    rng = np.random.default_rng(seed)
    xs = _rand(rng, (batch, n, n))
    np.testing.assert_array_equal(
        np.asarray(k.loopback_stream(xs, group=8 if batch % 8 == 0 else 4)),
        np.asarray(xs),
    )


@settings(max_examples=15, deadline=None)
@given(
    a=st.floats(
        min_value=-1e3, max_value=1e3, allow_nan=False, width=32
    ),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_saxpy_hypothesis(a, seed):
    rng = np.random.default_rng(seed)
    xs, ys = _rand(rng, (16, 16, 16)), _rand(rng, (16, 16, 16))
    av = jnp.float32(a)
    np.testing.assert_allclose(
        k.saxpy_stream(av, xs, ys, group=8),
        ref.saxpy_stream_ref(av, xs, ys),
        rtol=1e-5,
        atol=1e-3,
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_checksum_hypothesis(n, seed):
    rng = np.random.default_rng(seed)
    xs = _rand(rng, (16, n, n))
    np.testing.assert_allclose(
        k.checksum_stream(xs, group=8),
        ref.checksum_stream_ref(xs),
        rtol=1e-3,
        atol=1e-3,
    )


# ---------------------------------------------------------------------------
# Special values: the stream must propagate inf/nan like the oracle.
# ---------------------------------------------------------------------------


def test_matmul_inf_propagation():
    xs = jnp.full((8, 16, 16), jnp.inf, jnp.float32)
    ys = jnp.ones((8, 16, 16), jnp.float32)
    out = np.asarray(k.matmul_stream(xs, ys, group=8))
    assert np.all(np.isinf(out))


def test_matmul_nan_propagation():
    xs = jnp.ones((8, 16, 16), jnp.float32).at[0, 0, 0].set(jnp.nan)
    ys = jnp.ones((8, 16, 16), jnp.float32)
    out = np.asarray(k.matmul_stream(xs, ys, group=8))
    assert np.all(np.isnan(out[0, 0, :]))  # row 0 of matrix 0 contaminated
    assert not np.any(np.isnan(out[1:]))  # other matrices untouched
