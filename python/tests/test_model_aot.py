"""L2 + AOT path tests: variant registry shapes, HLO text lowering,
metadata contract consumed by the Rust runtime.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.VARIANTS))
def test_variant_lowers(name):
    lowered = model.lower_variant(name)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    # return_tuple=True => root is a tuple instruction
    assert "ROOT" in text and "tuple(" in text


@pytest.mark.parametrize("name", sorted(model.VARIANTS))
def test_variant_meta_contract(name):
    """The .meta.json sidecar must match the registered geometry."""
    with tempfile.TemporaryDirectory() as d:
        meta = aot.export_variant(name, d)
        assert os.path.exists(os.path.join(d, f"{name}.hlo.txt"))
        on_disk = json.load(open(os.path.join(d, f"{name}.meta.json")))
    assert on_disk == meta
    _, _, (batch, n) = model.VARIANTS[name]
    if name.startswith("matmul"):
        assert meta["inputs"] == [
            {"shape": [batch, n, n], "dtype": "float32"}
        ] * 2
        assert meta["outputs"] == [
            {"shape": [batch, n, n], "dtype": "float32"}
        ]
    for io in meta["inputs"] + meta["outputs"]:
        assert io["dtype"] == "float32"
    # Cluster bitstream-cache address: stable, sha256-shaped, and
    # derived from the (core, part, shell) triple the Rust side uses.
    assert meta["shell"] == aot.SHELL_VERSION
    assert meta["part"] == aot.DEFAULT_PART
    assert len(meta["cache_key"]) == 64
    assert meta["cache_key"] == aot.cache_key(name)


def test_cache_key_discriminates():
    """Mirrors rust/src/bitcache CacheKey::digest: any element of the
    (core, part, shell) triple changing must move the address."""
    a = aot.cache_key("matmul16_b64")
    assert a == aot.cache_key("matmul16_b64")
    assert a != aot.cache_key("matmul32_b64")
    assert a != aot.cache_key("matmul16_b64", part="xc6vlx240t")


def test_matmul_model_matches_kernel():
    from compile.kernels import ref

    rng = np.random.default_rng(11)
    xs = jnp.asarray(rng.standard_normal((64, 16, 16), dtype=np.float32))
    ys = jnp.asarray(rng.standard_normal((64, 16, 16), dtype=np.float32))
    (out,) = model.matmul_model(xs, ys)
    np.testing.assert_allclose(
        out, ref.matmul_stream_ref(xs, ys), rtol=1e-5, atol=1e-5
    )


def test_lowered_hlo_is_deterministic():
    """Same variant must lower to byte-identical HLO text (cacheable)."""
    a = aot.to_hlo_text(model.lower_variant("matmul16_b64"))
    b = aot.to_hlo_text(model.lower_variant("matmul16_b64"))
    assert a == b


def test_hlo_has_no_custom_calls():
    """interpret=True must lower to plain HLO ops — a Mosaic custom-call
    would be unexecutable on the Rust CPU PJRT client."""
    for name in model.VARIANTS:
        text = aot.to_hlo_text(model.lower_variant(name))
        assert "custom-call" not in text, f"{name} contains custom-call"


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(tmp_path),
            "--only",
            "matmul16_b64,loopback16_b256",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert out.returncode == 0, out.stderr
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert set(manifest) == {"matmul16_b64", "loopback16_b256"}
    for name, digest in manifest.items():
        assert len(digest) == 64
