"""AOT compile path: lower every registered user-core variant to HLO text.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).
The HLO text parser on the Rust side reassigns ids, so text round-trips
cleanly (see /opt/xla-example/README.md).

Besides the ``.hlo.txt`` module, a small ``.meta.json`` sidecar is
written per variant carrying the shape/dtype contract the Rust runtime
validates at load time — the same role the paper's bitfile metadata
plays for vFPGA region compatibility.

Run via ``make artifacts``; it is a no-op when artifacts are newer than
their Python inputs (Make-level dependency check).
"""

import argparse
import hashlib
import json
import os

from jax._src.lib import xla_client as xc

from compile import model

# Version of the RC2F static shell partial bitstreams are
# floorplanned against. Must match rust/src/bitcache/mod.rs
# SHELL_VERSION: the Rust cluster cache addresses artifacts by
# sha256("core|part|shell") and a mismatch here would orphan every
# AOT artifact this exporter stamps.
SHELL_VERSION = "rc2f-2.1"

# Default FPGA part the exported variants target (the VC707's).
DEFAULT_PART = "xc7vx485t"


def cache_key(core: str, part: str = DEFAULT_PART) -> str:
    """Content address of one compiled artifact, mirroring the Rust
    side's ``CacheKey::digest``: sha256 over the canonical
    ``core|part|shell`` triple."""
    triple = f"{core}|{part}|{SHELL_VERSION}"
    return hashlib.sha256(triple.encode()).hexdigest()


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    ``return_tuple=False``: every registered variant returns exactly
    one array, so the module root is that array directly. This lets
    the Rust runtime read results with a single
    ``copy_raw_to_host_sync`` instead of materializing a tuple Literal
    (one fewer copy on the per-chunk hot path — EXPERIMENTS.md §Perf).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _shape_meta(avals):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)} for a in avals
    ]


def export_variant(name: str, outdir: str) -> dict:
    """Lower one variant; write <name>.hlo.txt + <name>.meta.json."""
    lowered = model.lower_variant(name)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)

    args_info = jax_tree_leaves(lowered)
    meta = {
        "name": name,
        "inputs": args_info["inputs"],
        "outputs": args_info["outputs"],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "hlo_bytes": len(text),
        "part": DEFAULT_PART,
        "shell": SHELL_VERSION,
        "cache_key": cache_key(name),
    }
    with open(os.path.join(outdir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def jax_tree_leaves(lowered):
    """Extract flat input/output shape+dtype lists from a Lowered."""
    import jax

    in_leaves = jax.tree_util.tree_leaves(lowered.in_avals)
    out_leaves = jax.tree_util.tree_leaves(lowered.out_info)
    return {
        "inputs": _shape_meta(in_leaves),
        "outputs": _shape_meta(out_leaves),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts",
        help="artifact directory (default: ../artifacts)",
    )
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated variant names (default: all)",
    )
    args = ap.parse_args()
    outdir = args.out
    # `make artifacts` passes a file path for compatibility with the
    # original skeleton; accept either a dir or a path ending in .hlo.txt.
    if outdir.endswith(".hlo.txt"):
        outdir = os.path.dirname(outdir)
    os.makedirs(outdir, exist_ok=True)

    names = args.only.split(",") if args.only else list(model.VARIANTS)
    manifest = {}
    for name in names:
        meta = export_variant(name, outdir)
        manifest[name] = meta["sha256"]
        print(f"wrote {name}: {meta['hlo_bytes']} chars")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Sentinel consumed by the Makefile dependency rule.
    with open(os.path.join(outdir, "model.hlo.txt"), "w") as f:
        f.write("# see per-variant artifacts; manifest.json lists them\n")


if __name__ == "__main__":
    main()
