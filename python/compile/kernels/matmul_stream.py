"""L1 Pallas kernel: the paper's streaming matrix-multiplication user core.

The RC3E paper's example application (Section V) streams 100,000 matrix
pairs through an HLS-generated multiply core sitting behind the RC2F
FIFO interface. The TPU re-thinking of that design (DESIGN.md
§Hardware-Adaptation):

* the PCIe input FIFO becomes the grid's batch dimension — one matrix
  pair per grid step is "popped" from HBM into VMEM by the BlockSpec
  schedule, which Pallas double-buffers automatically (the role the
  paper's asynchronous FIFOs play);
* the HLS multiply datapath becomes one MXU matmul over the
  VMEM-resident (N, N) tiles;
* the output FIFO becomes the output BlockSpec writing the product tile
  back to HBM.

``interpret=True`` is mandatory on this image: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret mode
lowers to plain HLO ops, so the very same module text runs under the
Rust PJRT runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Matrices per grid step. For the small paper geometries (16x16, 32x32,
# fp32) a single pair underuses a VMEM tile; packing GROUP pairs per
# grid step amortizes grid/launch overhead exactly the way the paper
# streams 100k multiplications to amortize PCIe setup cost.
DEFAULT_GROUP = 8


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One grid step: multiply a group of G matrix pairs resident in VMEM.

    Block shapes are (G, N, N). A single dot_general with batch dims maps
    each pair onto the MXU; fp32 accumulate is requested explicitly so the
    result matches the f32 oracle bit-for-bit on CPU interpret mode.
    """
    o_ref[...] = jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("group",))
def matmul_stream(xs, ys, *, group=DEFAULT_GROUP):
    """Streaming batched matmul: f32[B,N,N] x f32[B,N,N] -> f32[B,N,N].

    B must be divisible by ``group`` (the AOT wrapper pads the final
    chunk host-side; the Rust streaming path always sends full chunks).
    """
    b, n, _ = xs.shape
    if b % group != 0:
        raise ValueError(f"batch {b} not divisible by group {group}")
    grid = (b // group,)
    spec = pl.BlockSpec((group, n, n), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, n, n), jnp.float32),
        interpret=True,
    )(xs, ys)


def _loopback_kernel(x_ref, o_ref):
    """RC2F test-loopback: copy the input block unmodified."""
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("group",))
def loopback_stream(xs, *, group=DEFAULT_GROUP):
    """Identity over the stream — backs the RC2F 'test loopback' signal."""
    b, n, _ = xs.shape
    if b % group != 0:
        raise ValueError(f"batch {b} not divisible by group {group}")
    spec = pl.BlockSpec((group, n, n), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _loopback_kernel,
        grid=(b // group,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xs.shape, xs.dtype),
        interpret=True,
    )(xs)


def _saxpy_kernel(a_ref, x_ref, y_ref, o_ref):
    """Elementwise a*x + y on a VMEM block (VPU, not MXU, bound)."""
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("group",))
def saxpy_stream(a, xs, ys, *, group=DEFAULT_GROUP):
    """Secondary user core for the BAaaS demo service: a*x + y."""
    b, n, _ = xs.shape
    if b % group != 0:
        raise ValueError(f"batch {b} not divisible by group {group}")
    spec = pl.BlockSpec((group, n, n), lambda i: (i, 0, 0))
    a_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _saxpy_kernel,
        grid=(b // group,),
        in_specs=[a_spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xs.shape, jnp.float32),
        interpret=True,
    )(a.reshape(1), xs, ys)


def _checksum_kernel(x_ref, o_ref):
    """Reduce each matrix in the group to a scalar sum."""
    o_ref[...] = jnp.sum(x_ref[...], axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("group",))
def checksum_stream(xs, *, group=DEFAULT_GROUP):
    """Per-matrix checksum core for the RC2F status-monitor demo."""
    b, n, _ = xs.shape
    if b % group != 0:
        raise ValueError(f"batch {b} not divisible by group {group}")
    in_spec = pl.BlockSpec((group, n, n), lambda i: (i, 0, 0))
    out_spec = pl.BlockSpec((group,), lambda i: (i,))
    return pl.pallas_call(
        _checksum_kernel,
        grid=(b // group,),
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(xs)
