"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks the Pallas
implementations against (L1 correctness signal). They intentionally use
only `jnp` primitives — no pallas — so a bug in the kernel plumbing
cannot hide in the oracle.
"""

import jax.numpy as jnp


def matmul_stream_ref(xs, ys):
    """Batched matrix multiply: the paper's streaming user core.

    Args:
      xs: f32[B, N, N] stream of left matrices.
      ys: f32[B, N, N] stream of right matrices.

    Returns:
      f32[B, N, N] — element i is ``xs[i] @ ys[i]``.
    """
    return jnp.einsum(
        "bij,bjk->bik", xs, ys, preferred_element_type=jnp.float32
    )


def loopback_ref(xs):
    """RC2F test-loopback control path: identity over the stream."""
    return xs


def saxpy_stream_ref(a, xs, ys):
    """Secondary user core (BAaaS demo service): a*x + y elementwise."""
    return a * xs + ys


def checksum_stream_ref(xs):
    """Per-matrix float checksum used by the RC2F status monitor demo."""
    return jnp.sum(xs, axis=(-2, -1))
