"""L2: JAX compute-graph definitions of the vFPGA user cores.

Each function here is one *user core variant* the RC3E cloud can load
into a vFPGA slot. They are thin jit-able wrappers over the L1 Pallas
kernels so that `aot.py` can lower each (variant, geometry, chunk)
combination to a single fused HLO module. The Rust runtime
(`rust/src/runtime/`) loads those modules and executes them on the
PJRT CPU client — Python never runs on the request path.

Variant registry
----------------
``VARIANTS`` maps a stable artifact name to a (fn, example-args builder)
pair. The artifact name doubles as the *core identifier* the Rust side
uses in bitstream metadata (`hls::CoreSpec::artifact`).
"""

import jax
import jax.numpy as jnp

from compile.kernels import matmul_stream as k


def matmul_model(xs, ys):
    """Streaming matmul core: the paper's Section-V example application.

    Lowered with ``group = batch`` (one grid step per streaming chunk):
    on CPU-interpret the Pallas grid loop is pure interpreter overhead
    (16x slower at group=8 — see EXPERIMENTS.md §Perf), while the
    VMEM-budget argument that motivates smaller groups only applies on
    real TPUs (DESIGN.md §Hardware-Adaptation). Correctness across
    group sizes is covered by the pytest group-invariance sweep.
    """
    return (k.matmul_stream(xs, ys, group=xs.shape[0]),)


def loopback_model(xs):
    """Test-loopback core (RC2F control signal 'test loopback')."""
    return (k.loopback_stream(xs, group=xs.shape[0]),)


def saxpy_model(a, xs, ys):
    """SAXPY core: the BAaaS background-acceleration demo service."""
    return (k.saxpy_stream(a, xs, ys, group=xs.shape[0]),)


def checksum_model(xs):
    """Checksum core: feeds the RC2F status monitor demo."""
    return (k.checksum_stream(xs, group=xs.shape[0]),)


def _mm_args(batch, n):
    spec = jax.ShapeDtypeStruct((batch, n, n), jnp.float32)
    return (spec, spec)


def _lb_args(batch, n):
    return (jax.ShapeDtypeStruct((batch, n, n), jnp.float32),)


def _saxpy_args(batch, n):
    spec = jax.ShapeDtypeStruct((batch, n, n), jnp.float32)
    return (jax.ShapeDtypeStruct((), jnp.float32), spec, spec)


def _ck_args(batch, n):
    return (jax.ShapeDtypeStruct((batch, n, n), jnp.float32),)


# artifact name -> (model fn, example-arg builder, (batch, n))
# Chunk sizes: 256 is the default streaming chunk for 16x16 (256*16*16*4B
# = 256 KiB per operand buffer); 32x32 uses 64 to keep per-chunk bytes
# equal (64*32*32*4B = 256 KiB) so the PCIe-link accounting in Rust sees
# identical DMA granularity, like the paper's fixed FIFO depth.
VARIANTS = {
    "matmul16_b256": (matmul_model, _mm_args, (256, 16)),
    "matmul16_b64": (matmul_model, _mm_args, (64, 16)),
    "matmul32_b64": (matmul_model, _mm_args, (64, 32)),
    "matmul32_b16": (matmul_model, _mm_args, (16, 32)),
    "loopback16_b256": (loopback_model, _lb_args, (256, 16)),
    "saxpy16_b256": (saxpy_model, _saxpy_args, (256, 16)),
    "checksum16_b256": (checksum_model, _ck_args, (256, 16)),
}


def lower_variant(name):
    """Lower one registered variant; returns the jax ``Lowered`` object."""
    fn, builder, (batch, n) = VARIANTS[name]
    return jax.jit(fn).lower(*builder(batch, n))
