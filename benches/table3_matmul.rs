//! Table III regenerator: streaming matrix-multiplication performance
//! (32-bit float) with up to four concurrent user cores.
//!
//! Paper rows (per core, 100,000 multiplications each):
//!   16×16:  1 core  0.73 s / 509 MB/s   (compute bound)
//!           2 cores 0.86 s / 398 MB/s   (link bound)
//!           4 cores 1.41 s / 198 MB/s   (link bound)
//!   32×32:  1 core  3.27 s / 279 MB/s   (compute bound)
//!           2 cores 3.43 s / 277 MB/s   (still compute bound)
//!
//! Area columns come from the HLS synthesis model (asserted close to
//! the paper); runtime/throughput are measured on the live streaming
//! path: real chunks through real FIFOs into PJRT matmuls, with the
//! virtual clock accounting the modeled FPGA/link timing. Wall-clock
//! columns show the real compute on this host.
//!
//! RC3E_T3_MULTS overrides the per-core multiplication count
//! (default 100,000, the paper's figure).

use std::sync::Arc;

use rc3e::hls::{CoreSpec, Synthesizer};
use rc3e::pcie::{DeviceLink, LinkParams};
use rc3e::rc2f::{StreamConfig, StreamRunner};
use rc3e::util::clock::VirtualClock;
use rc3e::util::table::Table;

struct Case {
    n: usize,
    cores: usize,
    paper_area: (u64, u64, u64, u64), // LUT FF DSP BRAM (total)
    paper_runtime_s: f64,
    paper_mbps: f64,
}

fn main() {
    rc3e::util::logging::init();
    let mults: u64 = std::env::var("RC3E_T3_MULTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(rc3e::paper::STREAM_MULTS);
    println!("streaming {mults} multiplications per core\n");

    let cases = [
        Case {
            n: 16,
            cores: 1,
            paper_area: (25_298, 41_654, 80, 14),
            paper_runtime_s: 0.73,
            paper_mbps: 509.0,
        },
        Case {
            n: 16,
            cores: 2,
            paper_area: (44_408, 76_963, 160, 19),
            paper_runtime_s: 0.86,
            paper_mbps: 398.0,
        },
        Case {
            n: 16,
            cores: 4,
            paper_area: (81_761, 146_974, 320, 28),
            paper_runtime_s: 1.41,
            paper_mbps: 198.0,
        },
        Case {
            n: 32,
            cores: 1,
            paper_area: (64_711, 125_715, 160, 14),
            paper_runtime_s: 3.27,
            paper_mbps: 279.0,
        },
        Case {
            n: 32,
            cores: 2,
            paper_area: (123_249, 245_103, 320, 19),
            paper_runtime_s: 3.43,
            paper_mbps: 277.0,
        },
    ];

    // ---------------- area table ------------------------------------
    let synth = Synthesizer::new();
    let mut area = Table::new(
        "Table III (area): matmul core resources on the XC7VX485T",
        &["design", "LUT", "paper", "FF", "paper", "DSP", "BRAM"],
    );
    for c in &cases {
        let report = synth.synthesize(&CoreSpec::matmul(c.n, "xc7vx485t"));
        let total = report.total_for(c.cores as u64);
        area.row(&[
            format!("{}x{} {}c", c.n, c.n, c.cores),
            total.lut.to_string(),
            c.paper_area.0.to_string(),
            total.ff.to_string(),
            c.paper_area.1.to_string(),
            format!("{} ({})", total.dsp, c.paper_area.2),
            format!("{} ({})", total.bram, c.paper_area.3),
        ]);
        assert!(
            (total.lut as f64 / c.paper_area.0 as f64 - 1.0).abs() < 0.02,
            "LUT {}x{} {}c",
            c.n,
            c.n,
            c.cores
        );
        assert_eq!(total.dsp, c.paper_area.2);
    }
    println!("{}", area.render());

    // ---------------- performance table -----------------------------
    let mut perf = Table::new(
        "Table III (performance): runtime + throughput per core",
        &[
            "design",
            "runtime/core",
            "paper",
            "MB/s per core",
            "paper",
            "ratio",
            "wall/core (host)",
        ],
    );
    for c in &cases {
        let clock = VirtualClock::new();
        let link =
            DeviceLink::new(Arc::clone(&clock), LinkParams::gen2_x4());
        let runner = StreamRunner::new(Arc::clone(&clock), link);
        let cfgs: Vec<StreamConfig> = (0..c.cores)
            .map(|i| {
                let base = if c.n == 16 {
                    StreamConfig::matmul16(mults)
                } else {
                    StreamConfig::matmul32(mults)
                };
                StreamConfig {
                    seed: 0x300 + i as u64,
                    validate_first_chunk: i == 0,
                    ..base
                }
            })
            .collect();
        let outs = runner.run_concurrent(&cfgs).unwrap();
        for o in &outs {
            assert_eq!(o.validation_failures, 0);
        }
        let runtime = outs
            .iter()
            .map(|o| o.virtual_total.as_secs_f64())
            .sum::<f64>()
            / c.cores as f64;
        let mbps = outs.iter().map(|o| o.virtual_mbps()).sum::<f64>()
            / c.cores as f64;
        let wall_mbps = outs.iter().map(|o| o.wall_mbps()).sum::<f64>()
            / c.cores as f64;
        // Scale the modeled runtime to the paper's 100k figure when
        // running a reduced workload.
        let runtime_100k = if mults == rc3e::paper::STREAM_MULTS {
            runtime
        } else {
            let stream = outs
                .iter()
                .map(|o| o.virtual_stream.as_secs_f64())
                .sum::<f64>()
                / c.cores as f64;
            stream * rc3e::paper::STREAM_MULTS as f64 / mults as f64
                + rc3e::rc2f::stream::STREAM_SETUP_MS / 1e3
        };
        perf.row(&[
            format!("{}x{} {}c", c.n, c.n, c.cores),
            format!("{runtime_100k:.2} s"),
            format!("{:.2} s", c.paper_runtime_s),
            format!("{mbps:.0}"),
            format!("{:.0}", c.paper_mbps),
            format!("{:.2}x", mbps / c.paper_mbps),
            format!("{wall_mbps:.0} MB/s"),
        ]);
        // The throughput *shape* must hold tightly (the model is
        // calibrated); runtimes may drift ~±20% (the paper's own
        // runtime and throughput columns are mutually inconsistent —
        // see DESIGN.md §2).
        assert!(
            (mbps / c.paper_mbps - 1.0).abs() < 0.08,
            "{}x{} {}c: {mbps} vs {}",
            c.n,
            c.n,
            c.cores,
            c.paper_mbps
        );
        assert!(
            (runtime_100k / c.paper_runtime_s - 1.0).abs() < 0.25,
            "{}x{} {}c runtime: {runtime_100k} vs {}",
            c.n,
            c.n,
            c.cores,
            c.paper_runtime_s
        );
    }
    println!("{}", perf.render());

    // Shape checks the paper's prose makes explicit.
    println!("shape checks:");
    println!("  - 1-core 16x16 is compute-bound below the 800 MB/s link");
    println!("  - 2-core 16x16 halves the link; 4-core quarters it");
    println!("  - 32x32 stays compute-bound even with 2 cores");
    println!("table3 OK");
}
