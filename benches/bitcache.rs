//! Bitstream-cache bench: what does each program-latency tier cost,
//! and what does a bounded cache buy under a skewed request mix?
//!
//! Tiers (virtual ms, deterministic — the paper's timing model, not
//! host wall time):
//!
//! * **cold** — no cached artifact: one AOT flow run (23 virtual
//!   minutes of synthesis + P&R) plus partial reconfiguration;
//! * **warm** — artifact in the cluster cache: PR only;
//! * **resident** — the region already holds the design: the
//!   hypervisor skips reconfiguration entirely.
//!
//! A zipfian request mix over a core universe twice the cache
//! capacity then measures the steady-state hit rate LRU sustains.
//!
//! With `BENCH_BASELINE_OUT=BENCH_baseline.json` the series are
//! written to the shared baseline file; `BENCH_QUICK=1` trims the
//! zipf draw count (CI bench-smoke).

use std::sync::Arc;

use rc3e::bitcache::{BitstreamCache, CacheKey};
use rc3e::bitstream::{Bitstream, BitstreamBuilder};
use rc3e::fpga::resources::Resources;
use rc3e::hls::flow::region_window;
use rc3e::hypervisor::Hypervisor;
use rc3e::metrics::Registry;
use rc3e::middleware::api::CompileSubmitRequest;
use rc3e::middleware::{Client, ManagementServer};
use rc3e::testing::baseline::{self, BaselineReport};
use rc3e::util::clock::VirtualClock;
use rc3e::util::rng::Rng;
use rc3e::util::table::Table;

/// Zipf draws for the hit-rate measurement.
fn zipf_draws() -> usize {
    if std::env::var("BENCH_QUICK").as_deref() == Ok("1") {
        200
    } else {
        2000
    }
}

/// Measure the three program tiers over the wire (virtual ms).
fn tier_latencies() -> (f64, f64, f64) {
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
    );
    let server = ManagementServer::spawn(hv, 69.0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let user = client.add_user("bench").unwrap().user;

    // Cold: the AOT flow builds the artifact, then first use pays PR.
    let sub = client
        .compile_submit(&CompileSubmitRequest {
            user,
            core: "matmul16".to_string(),
            part: None,
        })
        .unwrap();
    let result = client.job_wait_done(sub.job.unwrap()).unwrap();
    let build_ms = result.get("build_ms").as_f64().unwrap();
    let a = client.alloc_vfpga(user, None, None).unwrap();
    let first = client.program_core(user, a.alloc, "matmul16").unwrap();
    let cold_ms = build_ms + first.pr_ms;

    // Warm: a second region, same artifact — PR only.
    let b = client.alloc_vfpga(user, None, None).unwrap();
    let warm = client.program_core(user, b.alloc, "matmul16").unwrap();

    // Resident: the region already holds the design.
    let resident =
        client.program_core(user, b.alloc, "matmul16").unwrap();
    (cold_ms, warm.pr_ms, resident.pr_ms)
}

fn synthetic_bs(core: &str) -> Bitstream {
    BitstreamBuilder::partial("xc7vx485t", core)
        .resources(Resources::new(100, 100, 1, 1))
        .frames(region_window(0, 1))
        .payload_seed(core.len() as u64)
        .build()
}

/// Steady-state hit rate of a capacity-`cap` LRU cache under a
/// zipfian mix over `universe` distinct cores.
fn zipf_hit_rate(cap: usize, universe: usize, draws: usize) -> f64 {
    let cache =
        BitstreamCache::open(cap, None, Arc::new(Registry::new()));
    // Zipf weights 1/rank, drawn via the cumulative mass.
    let weights: Vec<f64> =
        (1..=universe).map(|k| 1.0 / k as f64).collect();
    let mass: f64 = weights.iter().sum();
    let mut rng = Rng::new(0x21BF);
    let mut hits = 0usize;
    for _ in 0..draws {
        let mut x = rng.next_f64() * mass;
        let mut pick = universe - 1;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                pick = i;
                break;
            }
            x -= *w;
        }
        let core = format!("core{pick:02}");
        let key = CacheKey::new(&core, "xc7vx485t");
        if cache.lookup(&key.digest()).is_some() {
            hits += 1;
        } else {
            cache
                .admit(&key, synthetic_bs(&core), region_window(0, 1))
                .unwrap();
        }
    }
    hits as f64 / draws as f64
}

fn main() {
    rc3e::util::logging::init();
    println!(
        "bitcache: program-latency tiers (virtual ms, deterministic) \
         and zipfian LRU hit rate\n"
    );
    let out = baseline::out_path();
    let mut report = match &out {
        Some(p) => BaselineReport::load_or_new(p),
        None => BaselineReport::new(),
    };

    let (cold_ms, warm_ms, resident_ms) = tier_latencies();
    // The resident tier is virtually free; clamp for a finite ratio.
    let warm_speedup = cold_ms / warm_ms;
    let resident_speedup = cold_ms / resident_ms.max(1.0);
    let mut t = Table::new(
        "program tiers (virtual ms)",
        &["tier", "ms", "speedup vs cold"],
    );
    t.row(&[
        "cold (flow + PR)".to_string(),
        format!("{cold_ms:.1}"),
        "1.0x".to_string(),
    ]);
    t.row(&[
        "warm (PR only)".to_string(),
        format!("{warm_ms:.1}"),
        format!("{warm_speedup:.0}x"),
    ]);
    t.row(&[
        "resident (skip)".to_string(),
        format!("{resident_ms:.1}"),
        format!("{resident_speedup:.0}x"),
    ]);
    print!("{}", t.render());

    let draws = zipf_draws();
    let hit_rate = zipf_hit_rate(8, 16, draws);
    println!(
        "\n    -> zipfian mix, 16 cores through a capacity-8 LRU \
         cache: {:.1}% hits over {draws} draws",
        hit_rate * 100.0
    );

    report.record_scalar("bitcache.cold_program_virtual_ms", cold_ms);
    report.record_scalar("bitcache.warm_program_virtual_ms", warm_ms);
    report.record_scalar(
        "bitcache.resident_program_virtual_ms",
        resident_ms,
    );
    report.record_scalar("bitcache.warm_speedup", warm_speedup);
    report.record_scalar("bitcache.resident_speedup", resident_speedup);
    report.record_scalar("bitcache.zipf_hit_rate", hit_rate);
    if let Some(p) = &out {
        report.save(p).unwrap();
        println!("baseline series written to {}\n", p.display());
    }
    println!(
        "reading: warm skips the 23-virtual-minute AOT flow and \
         resident additionally skips reconfiguration, so the tiers \
         should separate by orders of magnitude; the zipf hit rate \
         is what a half-sized cache holds onto under skew."
    );
}
