//! Bounded-wait curve: sweep offered load vs admission-wait
//! percentiles (the ROADMAP bench over the `sched.wait` histogram
//! that the `monitor` RPC already serves).
//!
//! For each offered load ρ (arrival rate as a fraction of the
//! cluster's service capacity at the mean hold time), a Poisson
//! arrival process submits requests through the unified admission
//! API; every granted lease is held for an exponentially-distributed
//! virtual time and released. The `sched.wait` histogram then gives
//! p50/p99/max of the *virtual* time requests spent queued.
//!
//! Two series: single-region requests and 2-region co-located gang
//! requests (all-or-nothing admission — a gang must find two free
//! regions on one device, so its waits grow faster with load).
//!
//! Everything runs on the virtual clock: the numbers are modeled
//! scheduler behavior, not host wall time.
//!
//! Run: `cargo bench --bench admission_wait`
//! (`BENCH_BASELINE_OUT=BENCH_baseline.json` also writes the curves
//! to the shared machine-readable baseline file.)

use std::sync::Arc;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::sched::{AdmissionRequest, Lease, RequestClass, Scheduler};
use rc3e::testing::baseline::{self, BaselineReport};
use rc3e::util::clock::{VirtualClock, VirtualTime};
use rc3e::util::ids::TicketId;
use rc3e::util::json::Json;
use rc3e::util::rng::Rng;
use rc3e::util::table::Table;

/// Requests per load point (per series).
const REQUESTS: usize = 300;
/// Mean lease hold time (virtual seconds).
const MEAN_HOLD_S: f64 = 8.0;
/// Tenants generating the load.
const TENANTS: usize = 8;

struct Point {
    p50_ms: f64,
    p99_ms: f64,
    max_ms: f64,
    mean_ms: f64,
}

fn run_series(gang: u32, load: f64, seed: u64) -> Point {
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::paper_testbed(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let sched = Scheduler::new(Arc::clone(&hv));
    let users: Vec<_> = (0..TENANTS)
        .map(|i| hv.add_user(&format!("tenant-{i}")))
        .collect();
    // 16 regions / gang concurrent leases; each occupies a slot for
    // MEAN_HOLD_S on average → service capacity in leases/sec.
    let capacity = 16.0 / f64::from(gang);
    let arrival_rate = load * capacity / MEAN_HOLD_S;
    let mut rng = Rng::new(seed);

    let mut submitted = 0usize;
    let mut next_arrival_ns =
        hv.clock.now().0 + to_ns(rng.next_exp(arrival_rate));
    // Outstanding tickets and live leases with their release times.
    let mut outstanding: Vec<TicketId> = Vec::new();
    let mut releases: Vec<(u64, Lease)> = Vec::new();

    loop {
        // Collect grants and schedule their releases.
        let mut i = 0;
        while i < outstanding.len() {
            match sched.poll_ticket(outstanding[i]) {
                Some(Ok(lease)) => {
                    outstanding.remove(i);
                    let hold =
                        to_ns(rng.next_exp(1.0 / MEAN_HOLD_S)).max(1);
                    releases.push((hv.clock.now().0 + hold, lease));
                }
                Some(Err(e)) => panic!("request failed: {e}"),
                None => i += 1,
            }
        }
        if submitted >= REQUESTS
            && outstanding.is_empty()
            && releases.is_empty()
        {
            break;
        }
        // Next event: soonest release, or the next arrival.
        let next_release = releases.iter().map(|(t, _)| *t).min();
        let next_event = match (submitted < REQUESTS, next_release) {
            (true, Some(r)) => next_arrival_ns.min(r),
            (true, None) => next_arrival_ns,
            (false, Some(r)) => r,
            (false, None) => {
                // Only queued work left; nothing can free capacity —
                // impossible by construction (grants always schedule
                // a release), but never spin.
                panic!("wedged: queued work with no pending release");
            }
        };
        let now = hv.clock.now().0;
        if next_event > now {
            hv.clock.advance(VirtualTime(next_event - now));
        }
        let now = hv.clock.now().0;
        // Fire due releases.
        let mut j = 0;
        while j < releases.len() {
            if releases[j].0 <= now {
                let (_, lease) = releases.remove(j);
                lease.release().unwrap();
            } else {
                j += 1;
            }
        }
        // Fire the arrival.
        if submitted < REQUESTS && next_arrival_ns <= now {
            let user = *rng.choose(&users);
            let mut req = AdmissionRequest::new(
                user,
                ServiceModel::RAaaS,
                RequestClass::Normal,
            );
            if gang > 1 {
                req = req.gang(gang).co_located();
            }
            outstanding.push(sched.enqueue(&req));
            submitted += 1;
            next_arrival_ns = now + to_ns(rng.next_exp(arrival_rate));
        }
    }

    let h = hv.metrics.histogram("sched.wait");
    Point {
        p50_ms: h.quantile_us(0.5) as f64 / 1e3,
        p99_ms: h.quantile_us(0.99) as f64 / 1e3,
        max_ms: h.max_us() as f64 / 1e3,
        mean_ms: h.mean_us() / 1e3,
    }
}

fn to_ns(secs: f64) -> u64 {
    VirtualTime::from_secs_f64(secs).0
}

fn main() {
    rc3e::util::logging::init();
    println!(
        "admission_wait: offered load vs sched.wait percentiles \
         ({REQUESTS} requests/point, mean hold {MEAN_HOLD_S} s, \
         16-region paper testbed; virtual ms)\n"
    );
    let out = baseline::out_path();
    let mut report = match &out {
        Some(p) => BaselineReport::load_or_new(p),
        None => BaselineReport::new(),
    };
    for (label, gang, seed, key) in [
        ("single-region", 1u32, 0xBEEF, "admission_wait.single_region"),
        ("gang-2 co-located", 2, 0xFEED, "admission_wait.gang2_colocated"),
    ] {
        let mut table = Table::new(
            &format!("series: {label}"),
            &["load", "p50 ms", "p99 ms", "max ms", "mean ms"],
        );
        let mut points = Vec::new();
        for load in [0.25, 0.5, 0.75, 0.9, 1.1] {
            let p = run_series(gang, load, seed);
            table.row(&[
                format!("{load:.2}"),
                format!("{:.1}", p.p50_ms),
                format!("{:.1}", p.p99_ms),
                format!("{:.1}", p.max_ms),
                format!("{:.1}", p.mean_ms),
            ]);
            points.push(Json::obj(vec![
                ("load", Json::from(load)),
                ("p50_ms", Json::from(p.p50_ms)),
                ("p99_ms", Json::from(p.p99_ms)),
                ("max_ms", Json::from(p.max_ms)),
                ("mean_ms", Json::from(p.mean_ms)),
            ]));
        }
        print!("{}\n", table.render());
        report.set(
            key,
            Json::obj(vec![
                ("kind", Json::from("virtual_ms_curve")),
                ("points", Json::Arr(points)),
            ]),
        );
    }
    if let Some(p) = &out {
        report.save(p).unwrap();
        println!("baseline series written to {}\n", p.display());
    }
    println!(
        "reading: waits stay bounded below saturation and explode past \
         it; the gang series saturates earlier because each admission \
         needs {MEAN_HOLD_S}-second possession of 2 co-located regions."
    );
}
