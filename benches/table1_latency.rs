//! Table I regenerator: latency of local and remote FPGA status
//! calls, full bitstream configuration and partial reconfiguration —
//! with and without the RC3E middleware.
//!
//! Paper rows (VC707):
//!   RC2F status:    11 ms local   /  80 ms over RC3E
//!   configuration:  28.370 s      /  29.513 s        (JTAG + USB)
//!   PR:             732 ms        /  912 ms
//!
//! All times are *virtual-clock* measurements of the same code paths
//! the system uses in production; the bench also reports the real
//! wall time of the full RPC round trip to show the middleware
//! itself (TCP + JSON + dispatch) is microseconds, not the modeled
//! milliseconds — the paper's point that RC3E overhead is
//! orchestration, not wire time.

use std::sync::Arc;

use rc3e::config::ClusterConfig;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::NodeId;
use rc3e::util::table::Table;

fn measure_virtual(
    clock: &Arc<VirtualClock>,
    mut f: impl FnMut(),
) -> (f64, f64) {
    let v0 = clock.now();
    let w0 = std::time::Instant::now();
    f();
    (
        clock.since(v0).as_millis_f64(),
        w0.elapsed().as_secs_f64() * 1e3,
    )
}

fn main() {
    rc3e::util::logging::init();

    // ---------------- local (without RC3E) -------------------------
    let clock = VirtualClock::new();
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            Arc::clone(&clock),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let fpga = hv.device_ids()[0];

    let (status_local, _) = measure_virtual(&clock, || {
        hv.status_local(fpga).unwrap();
    });

    // Raw device operations (what a node-local tool without RC3E
    // does): full configuration + PR straight on the device model.
    let dev = hv.device(fpga).unwrap();
    let design = rc3e::rc2f::Rc2fDesign::new(4);
    let full_bs = rc3e::bitstream::BitstreamBuilder::full(
        "xc7vx485t",
        &design.name(),
    )
    .resources(design.total_resources())
    .vfpga_regions(4)
    .build();
    let (config_local, _) = measure_virtual(&clock, || {
        dev.fpga.lock().unwrap().configure_full(&full_bs).unwrap();
    });
    let region = dev.fpga.lock().unwrap().regions()[0].id;
    let pr_bs = rc3e::bitstream::BitstreamBuilder::partial(
        "xc7vx485t",
        "matmul16",
    )
    .resources(rc3e::fpga::Resources::new(25_298, 41_654, 14, 80))
    .frames(rc3e::hls::flow::region_window(0, 1))
    .build();
    let (pr_local, _) = measure_virtual(&clock, || {
        dev.fpga
            .lock()
            .unwrap()
            .configure_partial(region, &pr_bs)
            .unwrap();
    });

    // ---------------- over RC3E (middleware + hypervisor) ----------
    let clock2 = VirtualClock::new();
    let hv2 = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            Arc::clone(&clock2),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv2), 69.0).unwrap();
    let agent =
        NodeAgent::spawn(Arc::clone(&hv2), NodeId(0), None).unwrap();
    server.register_agent(NodeId(0), agent.addr());
    let mut cli = Client::connect(server.addr()).unwrap();

    let (status_rc3e, status_wall) = measure_virtual(&clock2, || {
        cli.status(rc3e::util::ids::FpgaId(0)).unwrap();
    });

    // PR over RC3E: lease + program through the server.
    let user = cli.add_user("bench").unwrap().user;
    let lease = cli.alloc_vfpga(user, None, None).unwrap();
    let alloc = lease.alloc;
    let (pr_rc3e, pr_wall) = measure_virtual(&clock2, || {
        cli.program_core(user, alloc, "matmul16").unwrap();
    });
    cli.release(alloc).unwrap();

    // Full configuration over RC3E: RSaaS lease + program_full (an
    // async job on protocol 3 — submit + job_wait, two RPC hops).
    let lease = cli.alloc_physical(user).unwrap();
    let alloc = lease.alloc;
    let (config_rc3e, config_wall) = measure_virtual(&clock2, || {
        cli.program_full_sync(user, alloc, None).unwrap();
    });

    // ---------------- report ---------------------------------------
    let mut t = Table::new(
        "Table I: latency of status calls and configuration",
        &["operation", "measured", "paper", "ratio", "rpc wall (real)"],
    );
    let rows = [
        ("RC2F status, local", status_local, 11.0, f64::NAN),
        ("RC2F status, over RC3E", status_rc3e, 80.0, status_wall),
        ("configuration, local", config_local, 28_370.0, f64::NAN),
        (
            "configuration, over RC3E",
            config_rc3e,
            29_513.0,
            config_wall,
        ),
        ("PR, local", pr_local, 732.0, f64::NAN),
        ("PR, over RC3E", pr_rc3e, 912.0, pr_wall),
    ];
    for (name, measured, paper, wall) in rows {
        t.row(&[
            name.to_string(),
            format!("{measured:.1} ms"),
            format!("{paper:.1} ms"),
            format!("{:.3}x", measured / paper),
            if wall.is_nan() {
                "—".to_string()
            } else {
                format!("{wall:.2} ms")
            },
        ]);
    }
    println!("{}", t.render());
    for (name, measured, paper, _) in rows {
        assert!(
            (measured / paper - 1.0).abs() < 0.02,
            "{name}: {measured} vs paper {paper}"
        );
    }
    println!("table1 OK: all rows within 2% of the paper");
}
