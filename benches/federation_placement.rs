//! Federation placement bench: what does crossing a node boundary
//! cost the admission path?
//!
//! Two in-process node daemons register with a federated management
//! server over loopback TCP; the bench drives `alloc -> release`
//! cycles through the management client so every admission routes
//! remote (placement filter, daemon dial, `agent.admit`, token
//! homing). The same cycle against a classic single-process server
//! gives the local baseline. Both paths pay the identical typed-RPC
//! envelope cost; the delta is the federation machinery itself.
//!
//! Virtual time is free — the numbers are host wall time for the
//! middleware + placement machinery.
//!
//! Run: `cargo bench --bench federation_placement`

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use rc3e::cluster::NodeDaemon;
use rc3e::config::ClusterConfig;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::middleware::{Client, ManagementServer};
use rc3e::testing::baseline::{self, BaselineReport};
use rc3e::util::clock::VirtualClock;
use rc3e::util::table::Table;

const CYCLES: usize = 200;
const WARMUP: usize = 20;

/// Percentile over one run's samples (sorted in place), in ms.
fn pct(samples: &mut [f64], q: f64) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() as f64 - 1.0) * q).round() as usize;
    samples[idx]
}

/// Time `CYCLES` alloc→release round trips through `client`.
fn cycle_samples(client: &mut Client, user: rc3e::util::ids::UserId) -> Vec<f64> {
    for _ in 0..WARMUP {
        let lease = client.alloc_vfpga(user, None, None).unwrap();
        client.release(lease.alloc).unwrap();
    }
    let mut samples = Vec::with_capacity(CYCLES);
    for _ in 0..CYCLES {
        let t0 = Instant::now();
        let lease = client.alloc_vfpga(user, None, None).unwrap();
        client.release(lease.alloc).unwrap();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples
}

fn state_root() -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rc3e-bench-federation-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn main() {
    rc3e::util::logging::init();
    println!(
        "federation_placement: alloc->release round trip, remote \
         (2-node federated cluster) vs local (single process); \
         {CYCLES} cycles after {WARMUP} warmup\n"
    );
    let out = baseline::out_path();
    let mut report = match &out {
        Some(p) => BaselineReport::load_or_new(p),
        None => BaselineReport::new(),
    };
    let root = state_root();

    // ------------------------------------------------ local baseline
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
    );
    let local = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut lc = Client::connect(local.addr()).unwrap();
    let user = lc.add_user("bench-local").unwrap().user;
    let mut local_ms = cycle_samples(&mut lc, user);

    // ------------------------------------------- federated cluster
    let config = ClusterConfig::paper_testbed();
    let mgmt_hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::management_only(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server =
        ManagementServer::spawn_federated(Arc::clone(&mgmt_hv), 69.0, None)
            .unwrap();
    let mut daemons = Vec::new();
    for i in 0..config.nodes.len() {
        let daemon = NodeDaemon::spawn(
            &config,
            i,
            &root.join(format!("node{i}")),
            VirtualClock::new(),
        )
        .unwrap();
        daemon.register(server.addr()).unwrap();
        daemons.push(daemon);
    }
    let mut fc = Client::connect(server.addr()).unwrap();
    let user = fc.add_user("bench-fed").unwrap().user;
    let mut remote_ms = cycle_samples(&mut fc, user);

    // ------------------------------------------------------- report
    let local_p50 = pct(&mut local_ms, 0.50);
    let local_p99 = pct(&mut local_ms, 0.99);
    let remote_p50 = pct(&mut remote_ms, 0.50);
    let remote_p99 = pct(&mut remote_ms, 0.99);
    let mut t = Table::new(
        "alloc->release round trip (host wall ms)",
        &["path", "p50 ms", "p99 ms"],
    );
    t.row(&[
        "local (1 process)".to_string(),
        format!("{local_p50:.3}"),
        format!("{local_p99:.3}"),
    ]);
    t.row(&[
        "remote (federated)".to_string(),
        format!("{remote_p50:.3}"),
        format!("{remote_p99:.3}"),
    ]);
    print!("{}", t.render());
    println!(
        "\n    -> cross-node placement overhead: {:.2}x at p50",
        if local_p50 > 0.0 {
            remote_p50 / local_p50
        } else {
            0.0
        }
    );

    report.record_scalar("federation.admit_local_p50_ms", local_p50);
    report.record_scalar("federation.admit_local_p99_ms", local_p99);
    report.record_scalar("federation.admit_remote_p50_ms", remote_p50);
    report.record_scalar("federation.admit_remote_p99_ms", remote_p99);
    if let Some(p) = &out {
        report.save(p).unwrap();
        println!("baseline series written to {}\n", p.display());
    }
    println!(
        "reading: the remote path adds one placement filter pass and \
         one daemon round trip per admit/release; it should stay in \
         the same order of magnitude as local serving on loopback."
    );
    drop(fc);
    let _ = std::fs::remove_dir_all(&root);
}
