//! Journal-surface bench: raw log bandwidth and the price the
//! scheduler pays for write-ahead durability.
//!
//! * **append** — records/sec (and MB/s) through [`Journal::append`]
//!   at small (64 B, WAL-record-sized) and large (1 KiB) payloads,
//!   with segment rotation in the loop (256 KiB segments).
//! * **replay** — records/sec reading the whole log back with
//!   [`Journal::replay_from`], the cold-boot recovery path.
//! * **scheduler WAL overhead** — wall-clock admit→release cycles
//!   with the exact per-boundary [`SchedWal`] appends (one `Grant`,
//!   one `Release`) added to the loop, vs the bare cycle. The
//!   boundary *snapshot* predates the journal and is priced
//!   separately (`journal.sched_cycle_persistent`); the budget in
//!   `BENCH_baseline.json` — `sched.journal_overhead_pct < 10` —
//!   covers what the WAL itself adds to the admission hot path.
//!   `sched.wait` is virtual time and invariant under journaling, so
//!   the honest number is the wall-clock cycle.
//!
//! Run: `cargo bench --bench journal_throughput`
//! (`BENCH_BASELINE_OUT=BENCH_baseline.json` also writes the series
//! to the shared machine-readable baseline file.)

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::journal::{
    Journal, JournalConfig, LeaseRecord, MemberRecord, SchedWal,
    WalRecord,
};
use rc3e::sched::{
    AdmissionRequest, GrantTarget, RequestClass, Scheduler,
};
use rc3e::testing::baseline::{self, BaselineReport};
use rc3e::testing::Bencher;
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::{
    AllocationId, FpgaId, LeaseToken, NodeId, UserId, VfpgaId,
};

/// Records per append measurement.
const SMALL_RECORDS: u64 = 20_000;
const LARGE_RECORDS: u64 = 5_000;
/// Admit→release cycles per measured iteration.
const SCHED_CYCLES: usize = 200;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "rc3e-journal-bench-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Append `count` records of `payload_len` bytes; returns
/// (recs/s, MB/s, segments rotated through).
fn bench_append(
    dir: &Path,
    count: u64,
    payload_len: usize,
) -> (f64, f64, usize) {
    let log = Journal::open(
        dir,
        JournalConfig {
            segment_bytes: 256 * 1024,
            max_segments: 0,
        },
    )
    .unwrap();
    let payload = vec![0xA5u8; payload_len];
    let t0 = Instant::now();
    for _ in 0..count {
        log.append(&payload).unwrap();
    }
    log.sync().unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let recs_per_s = count as f64 / secs;
    let mb_per_s =
        (count as f64 * payload_len as f64) / secs / (1024.0 * 1024.0);
    (recs_per_s, mb_per_s, log.segment_count())
}

/// Read the whole log back (the recovery path); records/sec.
fn bench_replay(dir: &Path, expect: u64) -> f64 {
    let log = Journal::open(dir, JournalConfig::default()).unwrap();
    let t0 = Instant::now();
    let records = log.replay_from(1).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(records.len() as u64, expect, "replay lost records");
    expect as f64 / secs
}

fn boot_sched(persist_db: Option<&Path>) -> Arc<Scheduler> {
    let hv = Arc::new(
        Hypervisor::boot(
            &ClusterConfig::paper_testbed(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    match persist_db {
        Some(db) => Scheduler::new_persistent(hv, db).unwrap(),
        None => Scheduler::new(hv),
    }
}

/// A representative single-member grant record (what one RAaaS
/// admission writes to the WAL).
fn grant_record(user: UserId) -> LeaseRecord {
    LeaseRecord {
        token: LeaseToken::mint(),
        tenant: user,
        model: ServiceModel::RAaaS,
        class: RequestClass::Normal,
        co_located: false,
        wait_ns: 0,
        members: vec![MemberRecord {
            alloc: AllocationId(1),
            target: GrantTarget::Vfpga(VfpgaId(1), FpgaId(1), NodeId(1)),
            units: 1,
            started_ns: 0,
            charge_w: 10.0,
            migrations: 0,
        }],
    }
}

fn run_cycles(sched: &Arc<Scheduler>, user: UserId, wal: Option<&SchedWal>) {
    for _ in 0..SCHED_CYCLES {
        let lease = sched
            .admit(&AdmissionRequest::new(
                user,
                ServiceModel::RAaaS,
                RequestClass::Normal,
            ))
            .unwrap();
        if let Some(w) = wal {
            let rec = grant_record(user);
            let token = rec.token;
            w.append(&WalRecord::Grant(rec)).unwrap();
            w.append(&WalRecord::Release { token }).unwrap();
        }
        lease.release().unwrap();
    }
}

fn main() {
    rc3e::util::logging::init();
    println!(
        "journal_throughput: log bandwidth and scheduler WAL overhead\n"
    );
    let out = baseline::out_path();
    let mut report = match &out {
        Some(p) => BaselineReport::load_or_new(p),
        None => BaselineReport::new(),
    };

    let dir = scratch("append64");
    let (rps, mbps, segs) = bench_append(&dir, SMALL_RECORDS, 64);
    println!(
        "append  64 B x{SMALL_RECORDS}: {rps:.0} recs/s \
         ({mbps:.1} MB/s payload, {segs} segments)"
    );
    report.record_scalar("journal.append_64b_recs_per_s", rps);
    let replay_rps = bench_replay(&dir, SMALL_RECORDS);
    println!("replay  64 B x{SMALL_RECORDS}: {replay_rps:.0} recs/s");
    report.record_scalar("journal.replay_recs_per_s", replay_rps);
    let _ = std::fs::remove_dir_all(&dir);

    let dir = scratch("append1k");
    let (rps, mbps, segs) = bench_append(&dir, LARGE_RECORDS, 1024);
    println!(
        "append 1 KiB x{LARGE_RECORDS}: {rps:.0} recs/s \
         ({mbps:.1} MB/s payload, {segs} segments)"
    );
    report.record_scalar("journal.append_1k_mb_per_s", mbps);
    let _ = std::fs::remove_dir_all(&dir);
    println!();

    // WAL overhead on the admission hot path: the bare admit→release
    // cycle vs the same cycle plus the two records a journaled
    // boundary appends. Isolates the journal's marginal cost — the
    // boundary snapshot is priced separately below.
    let b = Bencher::new(1, 5);
    let plain = boot_sched(None);
    let user = plain.hv().add_user("bench");
    let base = b.run("admit_release bare", || {
        run_cycles(&plain, user, None);
    });
    println!("{}", base.line());

    let wal_dir = scratch("wal");
    let wal = SchedWal::open(&wal_dir).unwrap();
    let test = b.run("admit_release + WAL appends", || {
        run_cycles(&plain, user, Some(&wal));
    });
    println!("{}", test.line());
    let overhead = baseline::overhead_pct(&base, &test);
    println!(
        "scheduler WAL overhead: {overhead:.2}% per admit->release \
         cycle (budget < 10%)"
    );
    let _ = std::fs::remove_dir_all(&wal_dir);

    // Full persistent mode for context: WAL plus the per-boundary
    // snapshot (atomic temp+rename+fsync), i.e. what `serve --state`
    // actually runs.
    let state = scratch("sched");
    std::fs::create_dir_all(&state).unwrap();
    let db_path = state.join("devices.json");
    let persistent = boot_sched(Some(&db_path));
    let user = persistent.hv().add_user("bench");
    let full = b.run("admit_release persistent", || {
        run_cycles(&persistent, user, None);
    });
    println!("{}", full.line());
    let _ = std::fs::remove_dir_all(&state);

    report.record("journal.sched_cycle_bare", &base);
    report.record("journal.sched_cycle_walled", &test);
    report.record("journal.sched_cycle_persistent", &full);
    report.record_scalar("sched.journal_overhead_pct", overhead);

    if let Some(p) = &out {
        report.save(p).unwrap();
        println!("\nbaseline series written to {}", p.display());
    }
}
