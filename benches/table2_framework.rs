//! Table II regenerator: RC2F component resource utilization,
//! configuration-space access latency and per-core max FIFO
//! throughput for designs with 1, 2 and 4 vFPGAs.
//!
//! Resources come from the component model (calibrated, asserted
//! exact); latency and throughput are *measured* on the live system:
//! gcs/ucs accesses through a controller charging the virtual clock,
//! and loopback-style streams saturating the link arbiter.

use std::sync::Arc;

use rc3e::pcie::{BandwidthArbiter, DeviceLink, LinkParams};
use rc3e::rc2f::components::{ComponentModel, Rc2fDesign};
use rc3e::rc2f::controller::{gcs_reg, Controller};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::VfpgaId;
use rc3e::util::table::Table;

/// Measure per-core FIFO throughput with `n` concurrent saturating
/// streams on one link (loopback cores: link-bound by construction).
fn measured_fifo_mbps(n: usize) -> f64 {
    let clock = VirtualClock::new();
    let link = DeviceLink::new(Arc::clone(&clock), LinkParams::gen2_x4());
    let chunk: u64 = 256 * 1024;
    let per_stream: u64 = 100_000_000; // 100 MB each
    let handles: Vec<_> = (0..n).map(|_| link.inbound.open_stream()).collect();
    let mut worst = f64::MAX;
    for mut s in handles {
        let start = s.cursor();
        for _ in 0..(per_stream / chunk) {
            s.transfer(chunk);
        }
        let secs = s.elapsed_since(start).as_secs_f64();
        worst = worst.min(per_stream as f64 / 1e6 / secs);
    }
    worst
}

/// Measure the config-space access latency of an n-slot design:
/// one gcs status read + one ucs read (the paper's "latency" row is
/// gcs + ucs total).
fn measured_config_latency_ms(n: usize) -> f64 {
    let clock = VirtualClock::new();
    let ids: Vec<VfpgaId> = (0..n as u64).map(VfpgaId).collect();
    let c = Controller::new(Arc::clone(&clock), &ids);
    let v0 = clock.now();
    c.gcs_read(gcs_reg::STATUS).unwrap();
    c.ucs_read(VfpgaId(0), 0).unwrap();
    clock.since(v0).as_millis_f64()
}

fn main() {
    rc3e::util::logging::init();
    let device = rc3e::fpga::BoardSpec::vc707().resources;

    // ---------------- resource rows --------------------------------
    let mut res = Table::new(
        "Table II: RC2F component resources (XC7VX485T)",
        &["component", "LUT", "FF", "BRAM", "paper LUT/FF/BRAM"],
    );
    let pcie = ComponentModel::pcie_endpoint();
    let gcs = ComponentModel::control_gcs();
    res.row(&[
        "PCIe endpoint".into(),
        pcie.lut.to_string(),
        pcie.ff.to_string(),
        pcie.bram.to_string(),
        "3,268 / 3,592 / 8".into(),
    ]);
    res.row(&[
        "RC2F control (gcs)".into(),
        gcs.lut.to_string(),
        gcs.ff.to_string(),
        gcs.bram.to_string(),
        "125 / 255 / 1".into(),
    ]);
    let paper_totals = [
        (1usize, (7_082u64, 6_974u64, 13u64), (2.3, 1.2, 1.3)),
        (2, (7_807, 7_637, 17), (2.6, 1.3, 1.7)),
        (4, (8_532, 8_318, 25), (2.8, 1.4, 2.3)),
    ];
    for (n, (plut, pff, pbram), _) in paper_totals {
        let design = Rc2fDesign::new(n);
        let total = design.total_resources();
        res.row(&[
            format!("total, {n} vFPGA design"),
            total.lut.to_string(),
            total.ff.to_string(),
            total.bram.to_string(),
            format!("{plut} / {pff} / {pbram}"),
        ]);
        assert_eq!(total.lut, plut);
        assert_eq!(total.ff, pff);
        assert_eq!(total.bram, pbram);
    }
    println!("{}", res.render());

    // ---------------- utilization + latency + throughput -----------
    let mut t = Table::new(
        "Table II: utilization, latency, per-core max throughput",
        &[
            "vFPGAs",
            "util % (LUT/FF/BRAM)",
            "paper util %",
            "latency",
            "paper",
            "per-core max",
            "paper",
        ],
    );
    let paper_lat = [0.208, 0.221, 0.273];
    let paper_tp = [798.0, 397.0, 196.0];
    for (i, n) in [1usize, 2, 4].into_iter().enumerate() {
        let design = Rc2fDesign::new(n);
        let (lut, ff, bram, _) = design.utilization_pct(device);
        let lat = measured_config_latency_ms(n);
        let tp = measured_fifo_mbps(n);
        let (_, _, pcts) = paper_totals[i];
        t.row(&[
            n.to_string(),
            format!("{lut:.1} / {ff:.1} / {bram:.1}"),
            format!("{} / {} / {}", pcts.0, pcts.1, pcts.2),
            format!("{lat:.3} ms"),
            format!("{:.3} ms", paper_lat[i]),
            format!("{tp:.0} MB/s"),
            format!("{:.0} MB/s", paper_tp[i]),
        ]);
        assert!(
            (lat / paper_lat[i] - 1.0).abs() < 0.02,
            "latency {n}v: {lat} vs {}",
            paper_lat[i]
        );
        assert!(
            (tp / paper_tp[i] - 1.0).abs() < 0.03,
            "throughput {n}v: {tp} vs {}",
            paper_tp[i]
        );
    }
    println!("{}", t.render());

    // Headline claim: <3% of the device for the 4-vFPGA basic design.
    let max_pct = {
        let (l, f, b, d) = Rc2fDesign::new(4).utilization_pct(device);
        l.max(f).max(b).max(d)
    };
    assert!(max_pct < 3.0);
    println!(
        "headline check OK: 4-vFPGA basic design uses {max_pct:.1}% of the \
         XC7VX485T (paper: <3%)"
    );

    // Arbiter sanity: aggregated throughput never exceeds the cap.
    let clock = VirtualClock::new();
    let arb = BandwidthArbiter::new(Arc::clone(&clock), 800.0);
    let mut streams: Vec<_> = (0..4).map(|_| arb.open_stream()).collect();
    for s in &mut streams {
        s.transfer(10_000_000);
    }
    let agg =
        arb.bytes_total() as f64 / 1e6 / clock.now().as_secs_f64();
    assert!(agg <= 801.0, "aggregate {agg} exceeds link cap");
    println!("aggregate link check OK: {agg:.0} MB/s <= 800 MB/s");
}
