//! RPC-surface bench: wall-clock calls/sec through the typed v2
//! middleware, so API overhead enters the perf trajectory alongside
//! the Table I latency benches.
//!
//! * `status` — the cheapest read path (request parse, dispatch
//!   table, typed response, one frame each way);
//! * `alloc→release` — the full admission round trip through the
//!   cluster scheduler (quota check, placement, grant bookkeeping,
//!   release + queue pump);
//! * `job submit→wait` — the async-handle path for long operations
//!   (registry insert, worker thread, job_wait rendezvous).
//!
//! Virtual time is free here — the numbers below are real host wall
//! time for the middleware machinery itself.
//!
//! Run: `cargo bench --bench rpc_surface`

use std::sync::Arc;

use rc3e::hypervisor::Hypervisor;
use rc3e::middleware::{Client, ManagementServer};
use rc3e::testing::Bencher;
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::FpgaId;

fn calls_per_sec(median_s: f64) -> f64 {
    if median_s > 0.0 {
        1.0 / median_s
    } else {
        0.0
    }
}

fn main() {
    rc3e::util::logging::init();
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
    );
    let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let hello = client.hello().unwrap();
    println!(
        "rpc_surface: negotiated protocol {} (window [{}, {}])\n",
        hello.proto, hello.proto_min, hello.proto_max
    );
    let user = client.add_user("bench").unwrap().user;

    // -------------------------------------------------- status path
    let r = Bencher::new(20, 200).run("v2 status (typed)", || {
        client.status(FpgaId(0)).unwrap()
    });
    println!("{}\n    -> {:.0} calls/s", r.line(), calls_per_sec(r.median_s));

    // ------------------------------------------- alloc→release path
    let r = Bencher::new(5, 100).run("v2 alloc->release", || {
        let lease = client.alloc_vfpga(user, None, None).unwrap();
        client.release(lease.alloc).unwrap()
    });
    println!(
        "{}\n    -> {:.0} cycles/s ({:.0} RPCs/s)",
        r.line(),
        calls_per_sec(r.median_s),
        2.0 * calls_per_sec(r.median_s)
    );

    // ------------------------------------------ job handle overhead
    // program_full against a non-physical lease fails fast — what is
    // measured is the registry round trip (submit, worker, wait),
    // not the device work.
    let lease = client.alloc_vfpga(user, None, None).unwrap();
    let r = Bencher::new(5, 50).run("v2 job submit->wait", || {
        let job = client
            .program_full(user, lease.alloc, None)
            .unwrap()
            .job;
        client.job_wait(job, Some(10.0)).unwrap()
    });
    println!(
        "{}\n    -> {:.0} jobs/s",
        r.line(),
        calls_per_sec(r.median_s)
    );
    client.release(lease.alloc).unwrap();

    // Raw (untyped-params) envelope for comparison.
    let r = Bencher::new(20, 200).run("raw status (call_v2)", || {
        client
            .call_v2(
                "status",
                rc3e::util::json::Json::obj(vec![(
                    "fpga",
                    rc3e::util::json::Json::from("fpga-0"),
                )]),
            )
            .unwrap()
    });
    println!("{}\n    -> {:.0} calls/s", r.line(), calls_per_sec(r.median_s));
}
