//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. placement policy — consolidate-first (the paper's energy rule)
//!    vs round-robin: energy of a half-loaded cloud, and the flip
//!    side, per-core bandwidth;
//! 2. streaming chunk size — throughput vs the per-transfer overhead
//!    (why RC2F uses 256 KiB FIFO chunks);
//! 3. link capacity sweep — where the compute-bound → link-bound
//!    crossover of Table III moves as the Xillybus cap changes.

use std::sync::Arc;

use rc3e::config::ServiceModel;
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::pcie::{BandwidthArbiter, DeviceLink, LinkParams};
use rc3e::rc2f::{StreamConfig, StreamRunner};
use rc3e::util::clock::{VirtualClock, VirtualTime};
use rc3e::util::table::Table;

/// Ablation 1: energy + bandwidth of 4 one-region leases on a
/// 4-device cloud under each placement policy.
fn ablation_placement() {
    let mut t = Table::new(
        "Ablation: placement policy (4 leases, 4 devices, 1h steady state)",
        &[
            "policy",
            "devices touched",
            "draw (W)",
            "energy (kJ/h)",
            "link share/core",
        ],
    );
    for policy in [
        PlacementPolicy::ConsolidateFirst,
        PlacementPolicy::RoundRobin,
    ] {
        let clock = VirtualClock::new();
        let hv = Arc::new(
            Hypervisor::boot(
                &rc3e::config::ClusterConfig::paper_testbed(),
                Arc::clone(&clock),
                policy,
            )
            .unwrap(),
        );
        let user = hv.add_user("bench");
        let mut fpgas = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let (alloc, vfpga, fpga, _) =
                hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
            fpgas.insert(fpga);
            // Program a small core so the region clock is live.
            let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
            let bs = rc3e::bitstream::BitstreamBuilder::partial(
                "xc7vx485t",
                "loopback",
            )
            .resources(rc3e::fpga::Resources::new(660, 920, 1, 0))
            .frames(rc3e::hls::flow::region_window(slot, 1))
            .build();
            // ML605 devices need their own part id; retail: skip the
            // lease if the part mismatches (paper testbed mixes
            // boards).
            let part = hv
                .device(fpga)
                .unwrap()
                .fpga
                .lock()
                .unwrap()
                .board
                .part;
            let bs = if part == "xc7vx485t" {
                bs
            } else {
                rc3e::bitstream::BitstreamBuilder::partial(part, "loopback")
                    .resources(rc3e::fpga::Resources::new(660, 920, 1, 0))
                    .frames(rc3e::hls::flow::region_window(slot, 1))
                    .build()
            };
            hv.program_vfpga(alloc, user, &bs).unwrap();
        }
        let draw = hv.total_power_w();
        // Steady state for one virtual hour.
        let e0 = hv.total_energy_joules();
        clock.advance(VirtualTime::from_secs_f64(3600.0));
        let kj = (hv.total_energy_joules() - e0) / 1e3;
        // Bandwidth view: cores per device → link share per core.
        let worst_cores_per_dev = fpgas
            .iter()
            .map(|f| {
                let db = hv.db.lock().unwrap();
                db.used_regions(*f)
            })
            .max()
            .unwrap_or(1);
        let share = rc3e::paper::LINK_MBPS / worst_cores_per_dev as f64;
        t.row(&[
            format!("{policy:?}"),
            fpgas.len().to_string(),
            format!("{draw:.1}"),
            format!("{kj:.0}"),
            format!("{share:.0} MB/s"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "consolidate-first saves energy (fewer devices at active draw) at \
         the cost of per-core PCIe share — the paper's Section IV-B \
         tradeoff.\n"
    );
}

/// Ablation 2: chunk size vs effective link throughput.
fn ablation_chunk_size() {
    let mut t = Table::new(
        "Ablation: streaming chunk size (single stream, 800 MB/s link)",
        &["chunk", "throughput", "of cap"],
    );
    for chunk_kib in [4u64, 16, 64, 256, 1024] {
        let clock = VirtualClock::new();
        let arb = BandwidthArbiter::new(Arc::clone(&clock), 800.0);
        let mut s = arb.open_stream();
        let start = s.cursor();
        let total: u64 = 200_000_000;
        let chunk = chunk_kib * 1024;
        for _ in 0..(total / chunk) {
            s.transfer(chunk);
        }
        let secs = s.elapsed_since(start).as_secs_f64();
        let mbps = total as f64 / 1e6 / secs;
        t.row(&[
            format!("{chunk_kib} KiB"),
            format!("{mbps:.0} MB/s"),
            format!("{:.1}%", 100.0 * mbps / 800.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "256 KiB (the RC2F FIFO default) reaches Table II's 798 MB/s; \
         small chunks pay the per-transfer overhead.\n"
    );
}

/// Ablation 3: link-cap sweep — the Table III crossover.
fn ablation_link_cap() {
    let mults = 4_096;
    let mut t = Table::new(
        "Ablation: link capacity vs per-core throughput (16x16, 2 cores)",
        &["link cap", "per-core", "bound by"],
    );
    for cap in [400.0, 800.0, 1200.0, 1600.0] {
        let clock = VirtualClock::new();
        let params = LinkParams::gen2_x4();
        // Build a custom-capacity link.
        let link = Arc::new(rc3e::pcie::DeviceLink {
            params,
            inbound: BandwidthArbiter::new(Arc::clone(&clock), cap),
            outbound: BandwidthArbiter::new(Arc::clone(&clock), cap),
        });
        let runner = StreamRunner::new(Arc::clone(&clock), link);
        let cfgs: Vec<StreamConfig> = (0..2)
            .map(|i| StreamConfig {
                seed: i,
                validate_first_chunk: false,
                ..StreamConfig::matmul16(mults)
            })
            .collect();
        let outs = runner.run_concurrent(&cfgs).unwrap();
        let per_core = outs.iter().map(|o| o.virtual_mbps()).sum::<f64>()
            / outs.len() as f64;
        let bound = if per_core < 0.95 * rc3e::paper::MM16_1C_MBPS {
            "link"
        } else {
            "compute"
        };
        t.row(&[
            format!("{cap:.0} MB/s"),
            format!("{per_core:.0} MB/s"),
            bound.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "at ≥1200 MB/s two 16x16 cores become compute-bound again — the \
         crossover the paper attributes to the 800 MB/s Xillybus core \
         (Section IV-D2: 'will thus be replaced in further versions').\n"
    );
}


/// Ablation 4: placement policy under a *dynamic* session workload —
/// the static ablation above holds leases forever; this one drives
/// Poisson arrivals through the full admit→program→hold→release cycle
/// and compares admission, utilization and energy.
fn ablation_dynamic_workload() {
    let mut t = Table::new(
        "Ablation: placement under dynamic load (Poisson sessions)",
        &[
            "policy",
            "load",
            "admission",
            "mean util",
            "energy (kJ)",
            "mean setup",
        ],
    );
    for policy in [
        PlacementPolicy::ConsolidateFirst,
        PlacementPolicy::RoundRobin,
    ] {
        for (label, w) in [
            ("light", rc3e::hypervisor::CloudWorkload::light()),
            ("heavy", rc3e::hypervisor::CloudWorkload::heavy()),
        ] {
            let clock = VirtualClock::new();
            let hv = Hypervisor::boot(
                &rc3e::config::ClusterConfig::paper_testbed(),
                Arc::clone(&clock),
                policy,
            )
            .unwrap();
            let report =
                rc3e::hypervisor::workload::run(&hv, &w).unwrap();
            t.row(&[
                format!("{policy:?}"),
                label.to_string(),
                format!("{:.0}%", 100.0 * report.admission_rate()),
                format!("{:.1}%", 100.0 * report.mean_utilization),
                format!("{:.0}", report.energy_j / 1e3),
                format!("{:.0} ms", report.mean_setup_ms),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "under load, consolidation trades nothing on admission and wins \
         on energy; the PR+orchestration setup cost (~843 ms) is \
         constant across policies.\n"
    );
}

/// DeviceLink with public fields is needed by ablation 3.
fn main() {
    rc3e::util::logging::init();
    // Arbiter's DeviceLink is constructed directly above; silence the
    // unused import if compilation paths change.
    let _ = DeviceLink::new(
        VirtualClock::new(),
        LinkParams::gen2_x4(),
    );
    ablation_placement();
    ablation_dynamic_workload();
    ablation_chunk_size();
    ablation_link_cap();
    println!("ablations OK");
}
