//! Event-surface bench (protocol 3): wall-clock measurements of the
//! two new many-client mechanisms.
//!
//! * **fanout** — events/sec delivered through the [`EventBus`] as
//!   the subscriber count grows (1 → 16). Publishing is an O(1)
//!   enqueue; a dispatcher thread fans out into bounded
//!   per-subscriber queues. The interesting number is delivered
//!   events/sec (drained by subscribers), not enqueued/sec.
//! * **coalesced vs polling `job_wait`** — wakeup latency from job
//!   completion to the last of 16 waiters observing it. The
//!   coalesced path parks all 16 on one shared slot (one fanout);
//!   the polling path is what protocol-2 clients effectively did:
//!   each caller loops `job_status` on an interval.
//!
//! Run: `cargo bench --bench event_fanout`
//! (`BENCH_BASELINE_OUT=BENCH_baseline.json` also writes the series
//! to the shared machine-readable baseline file.)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use rc3e::metrics::Registry;
use rc3e::middleware::api::{Event, SubscriptionFilter};
use rc3e::middleware::{EventBus, JobRegistry, Scope};
use rc3e::testing::baseline::{self, BaselineReport};
use rc3e::util::json::Json;

const EVENTS: u64 = 20_000;
const WAITERS: usize = 16;

fn bench_fanout(subscribers: usize) -> f64 {
    let bus = EventBus::new();
    let stop = Arc::new(AtomicBool::new(false));
    let mut drains = Vec::new();
    for _ in 0..subscribers {
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        let stop = Arc::clone(&stop);
        drains.push(std::thread::spawn(move || {
            let mut seen = 0u64;
            loop {
                match sub.next(Duration::from_millis(20)) {
                    Some(_) => seen += 1,
                    None if stop.load(Ordering::SeqCst) => break,
                    None => {}
                }
            }
            (seen, sub.dropped())
        }));
    }
    let t0 = Instant::now();
    for i in 0..EVENTS {
        bus.publish(Event::QueueDepth { depth: i }, Scope::Public);
    }
    let publish_s = t0.elapsed().as_secs_f64();
    // Wait for the dispatcher to finish fanning out before stopping
    // the drains, so every queued event is observable.
    bus.flush();
    stop.store(true, Ordering::SeqCst);
    let mut delivered = 0u64;
    let mut dropped = 0u64;
    for d in drains {
        let (seen, lost) = d.join().unwrap();
        delivered += seen;
        dropped += lost;
    }
    let total_s = t0.elapsed().as_secs_f64();
    let eps = delivered as f64 / total_s;
    println!(
        "fanout x{subscribers:<2}: {EVENTS} events enqueued in \
         {publish_s:.4} s -> {eps:.0} delivered events/s \
         ({delivered} drained, {dropped} dropped to slow queues)"
    );
    eps
}

/// Latency from completion to every coalesced waiter waking.
fn bench_coalesced_wait() -> f64 {
    let metrics = Arc::new(Registry::new());
    let reg = JobRegistry::new();
    reg.set_metrics(Arc::clone(&metrics));
    let (tx, rx) = mpsc::channel::<()>();
    let job = Arc::clone(&reg).submit("bench", 0, None, move |_p| {
        let _ = rx.recv();
        Ok(Json::Null)
    });
    let waiters: Vec<_> = (0..WAITERS)
        .map(|_| {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                reg.wait(job, Duration::from_secs(30)).unwrap();
                Instant::now()
            })
        })
        .collect();
    while reg.waiters(job) < WAITERS as u64 {
        std::thread::sleep(Duration::from_micros(50));
    }
    let released = Instant::now();
    tx.send(()).unwrap();
    let last_wake = waiters
        .into_iter()
        .map(|w| w.join().unwrap())
        .max()
        .unwrap();
    let lat = last_wake.duration_since(released).as_secs_f64() * 1e3;
    println!(
        "coalesced job_wait: {WAITERS} waiters, one fanout \
         (counter {}), last wakeup {lat:.3} ms after completion",
        metrics.counter("jobs.wait.coalesced").get()
    );
    lat
}

/// The pre-v3 shape: every client polls `job_status` on an interval.
fn bench_polling_wait(poll_ms: u64) -> f64 {
    let reg = JobRegistry::new();
    let (tx, rx) = mpsc::channel::<()>();
    let job = Arc::clone(&reg).submit("bench", 0, None, move |_p| {
        let _ = rx.recv();
        Ok(Json::Null)
    });
    let start = Arc::new(std::sync::Barrier::new(WAITERS + 1));
    let waiters: Vec<_> = (0..WAITERS)
        .map(|_| {
            let reg = Arc::clone(&reg);
            let start = Arc::clone(&start);
            std::thread::spawn(move || {
                start.wait();
                loop {
                    if reg.status(job).unwrap().state.is_terminal() {
                        return Instant::now();
                    }
                    std::thread::sleep(Duration::from_millis(poll_ms));
                }
            })
        })
        .collect();
    start.wait();
    // Let every poller settle into its loop before completing.
    std::thread::sleep(Duration::from_millis(2 * poll_ms));
    let released = Instant::now();
    tx.send(()).unwrap();
    let last_wake = waiters
        .into_iter()
        .map(|w| w.join().unwrap())
        .max()
        .unwrap();
    let lat = last_wake.duration_since(released).as_secs_f64() * 1e3;
    println!(
        "polling job_status ({poll_ms} ms interval): {WAITERS} \
         pollers, last observation {lat:.3} ms after completion"
    );
    lat
}

fn main() {
    rc3e::util::logging::init();
    println!("event_fanout: delivered-throughput vs subscriber count");
    let out = baseline::out_path();
    let mut report = match &out {
        Some(p) => BaselineReport::load_or_new(p),
        None => BaselineReport::new(),
    };
    for n in [1, 2, 4, 8, 16] {
        let eps = bench_fanout(n);
        report.record_scalar(
            &format!("event_fanout.delivered_eps_x{n:02}"),
            eps,
        );
    }
    println!();
    let coalesced = bench_coalesced_wait();
    let polled = bench_polling_wait(5);
    println!(
        "wakeup latency: coalesced {coalesced:.3} ms vs polling \
         {polled:.3} ms ({:.1}x)",
        if coalesced > 0.0 {
            polled / coalesced
        } else {
            f64::INFINITY
        }
    );
    report.record_scalar("event_fanout.coalesced_wakeup_ms", coalesced);
    report.record_scalar("event_fanout.polling_wakeup_ms", polled);
    if let Some(p) = &out {
        report.save(p).unwrap();
        println!("baseline series written to {}", p.display());
    }
}
