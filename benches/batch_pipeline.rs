//! Inline vs pipelined batch throughput (the lifecycle refactor's
//! new measurable workload).
//!
//! The pipelined batch mode overlaps the partial reconfiguration of
//! job *k+1* with the streaming of job *k* on a double-buffered pair
//! of regions (two live leases), so the per-job PR cost
//! (732 ms PR + 111 ms orchestration on the paper testbed) hides
//! behind the previous job's stream instead of serializing with it.
//!
//! Both modes run the identical job list on a *scaled* virtual clock
//! (charged durations also sleep `charged / scale` of wall time, the
//! bench idiom from `util::clock`), so concurrency interleavings are
//! realistic and the wall-clock makespan shows the overlap directly;
//! the virtual makespans are reported next to it.
//!
//! Environment knobs: `RC3E_BP_JOBS` (default 4), `RC3E_BP_MULTS`
//! (default 50,000 multiplications per job), `RC3E_BP_SCALE`
//! (default 50).
//!
//! Run: `cargo bench --bench batch_pipeline` (needs `make artifacts`).

use std::sync::Arc;

use rc3e::batch::{BatchSystem, JobPayload, JobSpec, JobState};
use rc3e::hypervisor::Hypervisor;
use rc3e::rc2f::StreamConfig;
use rc3e::testing::mm16_partial;
use rc3e::util::clock::VirtualClock;
use rc3e::util::table::Table;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

struct Outcome {
    virtual_makespan_s: f64,
    wall_s: f64,
    done: usize,
}

fn run(pipelined: bool, jobs: usize, mults: u64, scale: u64) -> Outcome {
    let clock = VirtualClock::with_scale(scale);
    let hv = Arc::new(
        Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap(),
    );
    let bs = BatchSystem::new(Arc::clone(&hv));
    let user = hv.add_user("bench");
    let ids: Vec<_> = (0..jobs)
        .map(|i| {
            bs.submit(JobSpec {
                user,
                payload: JobPayload::UserBitfile(mm16_partial(0)),
                stream: StreamConfig {
                    seed: 0x700 + i as u64,
                    validate_first_chunk: i == 0,
                    ..StreamConfig::matmul16(mults)
                },
            })
        })
        .collect();
    let t0_virtual = clock.now();
    let t0_wall = std::time::Instant::now();
    if pipelined {
        bs.run_pipelined();
    } else {
        bs.run_to_completion();
    }
    let done = ids
        .iter()
        .filter(|id| matches!(bs.state(**id), Some(JobState::Done(_))))
        .count();
    Outcome {
        virtual_makespan_s: clock.since(t0_virtual).as_secs_f64(),
        wall_s: t0_wall.elapsed().as_secs_f64(),
        done,
    }
}

fn main() {
    rc3e::util::logging::init();
    if !rc3e::testing::artifacts_available("bench batch_pipeline") {
        println!("skipped: artifacts missing (run `make artifacts`)");
        return;
    }
    let jobs = env_u64("RC3E_BP_JOBS", 4) as usize;
    let mults = env_u64("RC3E_BP_MULTS", 50_000);
    let scale = env_u64("RC3E_BP_SCALE", 50);
    println!(
        "{jobs} jobs x {mults} multiplications, clock scale 1/{scale}\n"
    );

    let inline = run(false, jobs, mults, scale);
    let piped = run(true, jobs, mults, scale);

    let mut table = Table::new(
        "Batch throughput: inline vs pipelined (PR of k+1 under stream of k)",
        &[
            "mode",
            "done",
            "virtual makespan",
            "jobs/s (virtual)",
            "wall",
            "jobs/s (wall)",
        ],
    );
    for (name, o) in [("inline", &inline), ("pipelined", &piped)] {
        table.row(&[
            name.to_string(),
            format!("{}/{jobs}", o.done),
            format!("{:.3} s", o.virtual_makespan_s),
            format!("{:.3}", o.done as f64 / o.virtual_makespan_s),
            format!("{:.3} s", o.wall_s),
            format!("{:.3}", o.done as f64 / o.wall_s),
        ]);
    }
    println!("{}", table.render());
    println!(
        "wall speedup: {:.2}x, virtual speedup: {:.2}x",
        inline.wall_s / piped.wall_s,
        inline.virtual_makespan_s / piped.virtual_makespan_s
    );
    assert_eq!(inline.done, jobs, "inline jobs failed");
    assert_eq!(piped.done, jobs, "pipelined jobs failed");
}
