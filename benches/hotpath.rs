//! L3 hot-path microbenches (the §Perf baseline): the pieces on or
//! near the request path, measured in real wall time.
//!
//! * PJRT execute (per chunk, per mult) for both matmul geometries;
//! * FIFO push/pop round trip;
//! * link-arbiter accounting per chunk;
//! * JSON encode/decode of an RPC envelope;
//! * end-to-end RPC round trip over loopback TCP, with the flight
//!   recorder on and off (the tracing-overhead series);
//! * gcs/ucs controller access (lock + charge);
//! * the data plane (`dataplane.*`): copy-per-chunk vs pooled FIFO
//!   round trips (with allocations-per-chunk from the counting
//!   allocator) and JSON/base64 vs out-of-band binary wire framing.
//!
//! With `BENCH_BASELINE_OUT=BENCH_baseline.json` the series are also
//! written to the shared machine-readable baseline file. With
//! `BENCH_QUICK=1` iteration counts are trimmed to a smoke-test
//! scale (the CI bench-smoke step).

use std::sync::Arc;

use rc3e::fifo::{AsyncFifo, Chunk};
use rc3e::middleware::proto::{read_wire_frame, write_bin_chunk};
use rc3e::middleware::{Client, ManagementServer, StreamFrame, WireFrame};
use rc3e::pcie::{BandwidthArbiter, BufferPool};
use rc3e::runtime::{Engine, Tensor};
use rc3e::testing::baseline::{self, BaselineReport};
use rc3e::testing::Bencher;
use rc3e::util::bytes::{b64_decode, b64_encode};
use rc3e::util::clock::VirtualClock;
use rc3e::util::json::Json;
use rc3e::util::memprobe;
use rc3e::util::rng::Rng;

/// A [`Bencher`] honoring `BENCH_QUICK=1` (CI smoke runs).
fn bencher(warmup: usize, iters: usize) -> Bencher {
    if std::env::var("BENCH_QUICK").as_deref() == Ok("1") {
        Bencher::new(1, iters.min(3))
    } else {
        Bencher::new(warmup, iters)
    }
}

fn bench_engine(report: &mut BaselineReport) {
    let dir = rc3e::runtime::artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("engine: SKIPPED (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(7);
    for (artifact, batch, n) in
        [("matmul16_b256", 256usize, 16usize), ("matmul32_b64", 64, 32)]
    {
        engine.load(artifact).unwrap();
        let xs = Tensor::random(vec![batch, n, n], &mut rng);
        let ys = Tensor::random(vec![batch, n, n], &mut rng);
        let r = bencher(3, 20).run(&format!("pjrt {artifact}"), || {
            engine
                .matmul(artifact, xs.clone(), ys.clone())
                .unwrap()
                .data[0]
        });
        let per_mult_us = r.median_s / batch as f64 * 1e6;
        let in_mbps =
            (2 * batch * n * n * 4) as f64 / 1e6 / r.median_s;
        println!(
            "{}\n    -> {per_mult_us:.2} us/mult, input-side {in_mbps:.0} \
             MB/s on this host",
            r.line()
        );
        report.record(&format!("hotpath.pjrt_{artifact}"), &r);
    }
}

fn bench_fifo(report: &mut BaselineReport) {
    let fifo = AsyncFifo::rc2f_default("bench");
    let chunk = vec![0u8; 256 * 1024];
    let r = bencher(10, 1000).run("fifo push+pop 256KiB", || {
        fifo.push(chunk.clone()).unwrap();
        fifo.pop().unwrap()
    });
    println!("{}", r.line());
    report.record("hotpath.fifo_push_pop_256k", &r);
}

fn bench_arbiter(report: &mut BaselineReport) {
    let clock = VirtualClock::new();
    let arb = BandwidthArbiter::new(clock, 800.0);
    let mut s = arb.open_stream();
    let r = bencher(10, 1000).run("arbiter transfer accounting", || {
        s.transfer(256 * 1024)
    });
    println!("{}", r.line());
    report.record("hotpath.arbiter_transfer_256k", &r);
}

fn bench_json(report: &mut BaselineReport) {
    let envelope = Json::obj(vec![
        ("method", Json::from("stream")),
        (
            "params",
            Json::obj(vec![
                ("user", Json::from("user-3")),
                ("alloc", Json::from("alloc-17")),
                ("core", Json::from("matmul16")),
                ("mults", Json::from(100_000u64)),
            ]),
        ),
    ]);
    let text = envelope.to_string();
    let r = bencher(10, 2000).run("json encode RPC envelope", || {
        envelope.to_string()
    });
    println!("{}", r.line());
    report.record("hotpath.json_encode_envelope", &r);
    let r = bencher(10, 2000).run("json parse RPC envelope", || {
        Json::parse(&text).unwrap()
    });
    println!("{}", r.line());
    report.record("hotpath.json_parse_envelope", &r);
}

fn bench_rpc(report: &mut BaselineReport) {
    let hv = Arc::new(
        rc3e::hypervisor::Hypervisor::boot(
            &rc3e::config::ClusterConfig::single_vc707(),
            VirtualClock::new(),
            rc3e::hypervisor::PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(hv, 69.0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    // Tracing-overhead series: the same loopback round trip with the
    // flight recorder off, then on (root span per RPC recorded).
    server.tracer().set_enabled(false);
    let off = bencher(5, 200)
        .run("rpc hello round trip (tracing off)", || {
            client.hello().unwrap()
        });
    println!("{}", off.line());
    server.tracer().set_enabled(true);
    let on = bencher(5, 200)
        .run("rpc hello round trip (tracing on)", || {
            client.hello().unwrap()
        });
    println!("{}", on.line());
    let pct = baseline::overhead_pct(&off, &on);
    println!("    -> flight-recorder overhead {pct:+.2}% of the round trip");
    report.record("hotpath.rpc_hello_untraced", &off);
    report.record("hotpath.rpc_hello_traced", &on);
    report.record_scalar("hotpath.tracing_overhead_pct", pct);
}

fn bench_controller(report: &mut BaselineReport) {
    let clock = VirtualClock::new();
    let ids: Vec<_> = (0..4).map(rc3e::util::ids::VfpgaId).collect();
    let c = rc3e::rc2f::Controller::new(clock, &ids);
    let r = bencher(10, 2000).run("gcs read (wall, ex-model)", || {
        c.gcs_read(rc3e::rc2f::controller::gcs_reg::STATUS).unwrap()
    });
    println!("{}", r.line());
    report.record("hotpath.gcs_read", &r);
}

/// Data-plane FIFO round trips: the old copy-per-chunk path (a fresh
/// `Vec` allocated and cloned for every chunk) against the pooled
/// path (producer fills a recycled slot in place; the queue and the
/// consumer only move the handle). Steady-state allocations per
/// chunk come from the counting global allocator.
fn bench_dataplane_fifo(report: &mut BaselineReport) {
    const CHUNK: usize = 256 * 1024;
    let chunk = vec![0x5Au8; CHUNK];

    let fifo = AsyncFifo::rc2f_default("dp_copy");
    let r_copy =
        bencher(10, 1000).run("dataplane fifo copy 256KiB", || {
            fifo.push(chunk.clone()).unwrap();
            fifo.pop().unwrap().unwrap().len()
        });
    println!("{}", r_copy.line());
    let a0 = memprobe::thread_allocations();
    for _ in 0..64 {
        fifo.push(chunk.clone()).unwrap();
        fifo.pop().unwrap();
    }
    let allocs_copy =
        (memprobe::thread_allocations() - a0) as f64 / 64.0;

    let fifo = AsyncFifo::rc2f_default("dp_pooled");
    let pool = BufferPool::new("dp_pooled", CHUNK, 4);
    let r_pooled =
        bencher(10, 1000).run("dataplane fifo pooled 256KiB", || {
            let mut buf = pool.acquire();
            buf.fill_from(&chunk);
            fifo.push_chunk(Chunk::Pooled(buf)).unwrap();
            fifo.pop_chunk().unwrap().unwrap().len()
        });
    println!("{}", r_pooled.line());
    let a0 = memprobe::thread_allocations();
    for _ in 0..64 {
        let mut buf = pool.acquire();
        buf.fill_from(&chunk);
        fifo.push_chunk(Chunk::Pooled(buf)).unwrap();
        fifo.pop_chunk().unwrap();
    }
    let allocs_pooled =
        (memprobe::thread_allocations() - a0) as f64 / 64.0;

    let copy_cps = 1.0 / r_copy.median_s;
    let pooled_cps = 1.0 / r_pooled.median_s;
    println!(
        "    -> copy {copy_cps:.0} chunks/s ({allocs_copy:.1} \
         allocs/chunk), pooled {pooled_cps:.0} chunks/s \
         ({allocs_pooled:.1} allocs/chunk), {:.2}x",
        pooled_cps / copy_cps
    );
    report.record("dataplane.fifo_roundtrip_copy_256k", &r_copy);
    report.record("dataplane.fifo_roundtrip_pooled_256k", &r_pooled);
    report.record_scalar("dataplane.fifo_copy_chunks_per_sec", copy_cps);
    report.record_scalar(
        "dataplane.fifo_pooled_chunks_per_sec",
        pooled_cps,
    );
    report
        .record_scalar("dataplane.fifo_speedup", pooled_cps / copy_cps);
    report.record_scalar("dataplane.alloc_per_chunk_copy", allocs_copy);
    report
        .record_scalar("dataplane.alloc_per_chunk_pooled", allocs_pooled);
}

/// Wire framing for one 256 KiB payload chunk, written to and read
/// back from memory: the protocol-3 JSON fallback (base64 payload in
/// a `stream_data` event frame) against the protocol-4 out-of-band
/// binary frame.
fn bench_dataplane_wire(report: &mut BaselineReport) {
    const CHUNK: usize = 256 * 1024;
    let payload = vec![0xA5u8; CHUNK];
    let mut buf: Vec<u8> = Vec::with_capacity(2 * CHUNK);

    let r_json =
        bencher(5, 200).run("dataplane wire json+b64 256KiB", || {
            buf.clear();
            let b64 = b64_encode(&payload);
            let frame = StreamFrame::event(
                1,
                Json::obj(vec![
                    ("type", Json::from("stream_data")),
                    ("b64", Json::from(b64.as_str())),
                ]),
            );
            rc3e::middleware::write_frame(&mut buf, &frame.to_json())
                .unwrap();
            let mut r: &[u8] = &buf;
            match read_wire_frame(&mut r).unwrap().unwrap() {
                WireFrame::Json(v) => {
                    let f = StreamFrame::from_json(&v).unwrap();
                    let ev = f.event.unwrap();
                    b64_decode(ev.get("b64").as_str().unwrap())
                        .unwrap()
                        .len()
                }
                WireFrame::Bin(_) => unreachable!("json framing"),
            }
        });
    println!("{}", r_json.line());

    let r_bin =
        bencher(5, 200).run("dataplane wire binary 256KiB", || {
            buf.clear();
            write_bin_chunk(&mut buf, 0, 1, &payload).unwrap();
            let mut r: &[u8] = &buf;
            match read_wire_frame(&mut r).unwrap().unwrap() {
                WireFrame::Bin(b) => b.payload.len(),
                WireFrame::Json(_) => unreachable!("binary framing"),
            }
        });
    println!("{}", r_bin.line());

    let json_mbps = CHUNK as f64 / 1e6 / r_json.median_s;
    let bin_mbps = CHUNK as f64 / 1e6 / r_bin.median_s;
    println!(
        "    -> json {json_mbps:.0} MB/s, binary {bin_mbps:.0} MB/s, \
         {:.1}x",
        bin_mbps / json_mbps
    );
    report.record("dataplane.wire_json_roundtrip_256k", &r_json);
    report.record("dataplane.wire_binary_roundtrip_256k", &r_bin);
    report.record_scalar("dataplane.wire_json_mbps", json_mbps);
    report.record_scalar("dataplane.wire_binary_mbps", bin_mbps);
    report
        .record_scalar("dataplane.wire_speedup", bin_mbps / json_mbps);
}

fn main() {
    rc3e::util::logging::init();
    println!("L3 hot-path microbenches (wall time)\n");
    let out = baseline::out_path();
    let mut report = match &out {
        Some(p) => BaselineReport::load_or_new(p),
        None => BaselineReport::new(),
    };
    bench_engine(&mut report);
    bench_fifo(&mut report);
    bench_arbiter(&mut report);
    bench_json(&mut report);
    bench_rpc(&mut report);
    bench_controller(&mut report);
    bench_dataplane_fifo(&mut report);
    bench_dataplane_wire(&mut report);
    if let Some(p) = &out {
        report.save(p).unwrap();
        println!("\nbaseline series written to {}", p.display());
    }
    println!("\nhotpath OK");
}
