//! L3 hot-path microbenches (the §Perf baseline): the pieces on or
//! near the request path, measured in real wall time.
//!
//! * PJRT execute (per chunk, per mult) for both matmul geometries;
//! * FIFO push/pop round trip;
//! * link-arbiter accounting per chunk;
//! * JSON encode/decode of an RPC envelope;
//! * end-to-end RPC round trip over loopback TCP;
//! * gcs/ucs controller access (lock + charge).

use std::sync::Arc;

use rc3e::fifo::AsyncFifo;
use rc3e::middleware::{Client, ManagementServer};
use rc3e::pcie::BandwidthArbiter;
use rc3e::runtime::{Engine, Tensor};
use rc3e::testing::Bencher;
use rc3e::util::clock::VirtualClock;
use rc3e::util::json::Json;
use rc3e::util::rng::Rng;

fn bench_engine() {
    let dir = rc3e::runtime::artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("engine: SKIPPED (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(7);
    for (artifact, batch, n) in
        [("matmul16_b256", 256usize, 16usize), ("matmul32_b64", 64, 32)]
    {
        engine.load(artifact).unwrap();
        let xs = Tensor::random(vec![batch, n, n], &mut rng);
        let ys = Tensor::random(vec![batch, n, n], &mut rng);
        let r = Bencher::new(3, 20).run(&format!("pjrt {artifact}"), || {
            engine
                .matmul(artifact, xs.clone(), ys.clone())
                .unwrap()
                .data[0]
        });
        let per_mult_us = r.median_s / batch as f64 * 1e6;
        let in_mbps =
            (2 * batch * n * n * 4) as f64 / 1e6 / r.median_s;
        println!(
            "{}\n    -> {per_mult_us:.2} us/mult, input-side {in_mbps:.0} \
             MB/s on this host",
            r.line()
        );
    }
}

fn bench_fifo() {
    let fifo = AsyncFifo::rc2f_default("bench");
    let chunk = vec![0u8; 256 * 1024];
    let r = Bencher::new(10, 1000).run("fifo push+pop 256KiB", || {
        fifo.push(chunk.clone()).unwrap();
        fifo.pop().unwrap()
    });
    println!("{}", r.line());
}

fn bench_arbiter() {
    let clock = VirtualClock::new();
    let arb = BandwidthArbiter::new(clock, 800.0);
    let mut s = arb.open_stream();
    let r = Bencher::new(10, 1000).run("arbiter transfer accounting", || {
        s.transfer(256 * 1024)
    });
    println!("{}", r.line());
}

fn bench_json() {
    let envelope = Json::obj(vec![
        ("method", Json::from("stream")),
        (
            "params",
            Json::obj(vec![
                ("user", Json::from("user-3")),
                ("alloc", Json::from("alloc-17")),
                ("core", Json::from("matmul16")),
                ("mults", Json::from(100_000u64)),
            ]),
        ),
    ]);
    let text = envelope.to_string();
    let r = Bencher::new(10, 2000).run("json encode RPC envelope", || {
        envelope.to_string()
    });
    println!("{}", r.line());
    let r = Bencher::new(10, 2000).run("json parse RPC envelope", || {
        Json::parse(&text).unwrap()
    });
    println!("{}", r.line());
}

fn bench_rpc() {
    let hv = Arc::new(
        rc3e::hypervisor::Hypervisor::boot(
            &rc3e::config::ClusterConfig::single_vc707(),
            VirtualClock::new(),
            rc3e::hypervisor::PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(hv, 69.0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let r = Bencher::new(5, 200).run("rpc hello round trip (wall)", || {
        client.hello().unwrap()
    });
    println!("{}", r.line());
}

fn bench_controller() {
    let clock = VirtualClock::new();
    let ids: Vec<_> = (0..4).map(rc3e::util::ids::VfpgaId).collect();
    let c = rc3e::rc2f::Controller::new(clock, &ids);
    let r = Bencher::new(10, 2000).run("gcs read (wall, ex-model)", || {
        c.gcs_read(rc3e::rc2f::controller::gcs_reg::STATUS).unwrap()
    });
    println!("{}", r.line());
}

fn main() {
    rc3e::util::logging::init();
    println!("L3 hot-path microbenches (wall time)\n");
    bench_engine();
    bench_fifo();
    bench_arbiter();
    bench_json();
    bench_rpc();
    bench_controller();
    println!("\nhotpath OK");
}
