//! L3 hot-path microbenches (the §Perf baseline): the pieces on or
//! near the request path, measured in real wall time.
//!
//! * PJRT execute (per chunk, per mult) for both matmul geometries;
//! * FIFO push/pop round trip;
//! * link-arbiter accounting per chunk;
//! * JSON encode/decode of an RPC envelope;
//! * end-to-end RPC round trip over loopback TCP, with the flight
//!   recorder on and off (the tracing-overhead series);
//! * gcs/ucs controller access (lock + charge).
//!
//! With `BENCH_BASELINE_OUT=BENCH_baseline.json` the series are also
//! written to the shared machine-readable baseline file.

use std::sync::Arc;

use rc3e::fifo::AsyncFifo;
use rc3e::middleware::{Client, ManagementServer};
use rc3e::pcie::BandwidthArbiter;
use rc3e::runtime::{Engine, Tensor};
use rc3e::testing::baseline::{self, BaselineReport};
use rc3e::testing::Bencher;
use rc3e::util::clock::VirtualClock;
use rc3e::util::json::Json;
use rc3e::util::rng::Rng;

fn bench_engine(report: &mut BaselineReport) {
    let dir = rc3e::runtime::artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("engine: SKIPPED (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(7);
    for (artifact, batch, n) in
        [("matmul16_b256", 256usize, 16usize), ("matmul32_b64", 64, 32)]
    {
        engine.load(artifact).unwrap();
        let xs = Tensor::random(vec![batch, n, n], &mut rng);
        let ys = Tensor::random(vec![batch, n, n], &mut rng);
        let r = Bencher::new(3, 20).run(&format!("pjrt {artifact}"), || {
            engine
                .matmul(artifact, xs.clone(), ys.clone())
                .unwrap()
                .data[0]
        });
        let per_mult_us = r.median_s / batch as f64 * 1e6;
        let in_mbps =
            (2 * batch * n * n * 4) as f64 / 1e6 / r.median_s;
        println!(
            "{}\n    -> {per_mult_us:.2} us/mult, input-side {in_mbps:.0} \
             MB/s on this host",
            r.line()
        );
        report.record(&format!("hotpath.pjrt_{artifact}"), &r);
    }
}

fn bench_fifo(report: &mut BaselineReport) {
    let fifo = AsyncFifo::rc2f_default("bench");
    let chunk = vec![0u8; 256 * 1024];
    let r = Bencher::new(10, 1000).run("fifo push+pop 256KiB", || {
        fifo.push(chunk.clone()).unwrap();
        fifo.pop().unwrap()
    });
    println!("{}", r.line());
    report.record("hotpath.fifo_push_pop_256k", &r);
}

fn bench_arbiter(report: &mut BaselineReport) {
    let clock = VirtualClock::new();
    let arb = BandwidthArbiter::new(clock, 800.0);
    let mut s = arb.open_stream();
    let r = Bencher::new(10, 1000).run("arbiter transfer accounting", || {
        s.transfer(256 * 1024)
    });
    println!("{}", r.line());
    report.record("hotpath.arbiter_transfer_256k", &r);
}

fn bench_json(report: &mut BaselineReport) {
    let envelope = Json::obj(vec![
        ("method", Json::from("stream")),
        (
            "params",
            Json::obj(vec![
                ("user", Json::from("user-3")),
                ("alloc", Json::from("alloc-17")),
                ("core", Json::from("matmul16")),
                ("mults", Json::from(100_000u64)),
            ]),
        ),
    ]);
    let text = envelope.to_string();
    let r = Bencher::new(10, 2000).run("json encode RPC envelope", || {
        envelope.to_string()
    });
    println!("{}", r.line());
    report.record("hotpath.json_encode_envelope", &r);
    let r = Bencher::new(10, 2000).run("json parse RPC envelope", || {
        Json::parse(&text).unwrap()
    });
    println!("{}", r.line());
    report.record("hotpath.json_parse_envelope", &r);
}

fn bench_rpc(report: &mut BaselineReport) {
    let hv = Arc::new(
        rc3e::hypervisor::Hypervisor::boot(
            &rc3e::config::ClusterConfig::single_vc707(),
            VirtualClock::new(),
            rc3e::hypervisor::PlacementPolicy::ConsolidateFirst,
        )
        .unwrap(),
    );
    let server = ManagementServer::spawn(hv, 69.0).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    // Tracing-overhead series: the same loopback round trip with the
    // flight recorder off, then on (root span per RPC recorded).
    server.tracer().set_enabled(false);
    let off = Bencher::new(5, 200)
        .run("rpc hello round trip (tracing off)", || {
            client.hello().unwrap()
        });
    println!("{}", off.line());
    server.tracer().set_enabled(true);
    let on = Bencher::new(5, 200)
        .run("rpc hello round trip (tracing on)", || {
            client.hello().unwrap()
        });
    println!("{}", on.line());
    let pct = baseline::overhead_pct(&off, &on);
    println!("    -> flight-recorder overhead {pct:+.2}% of the round trip");
    report.record("hotpath.rpc_hello_untraced", &off);
    report.record("hotpath.rpc_hello_traced", &on);
    report.record_scalar("hotpath.tracing_overhead_pct", pct);
}

fn bench_controller(report: &mut BaselineReport) {
    let clock = VirtualClock::new();
    let ids: Vec<_> = (0..4).map(rc3e::util::ids::VfpgaId).collect();
    let c = rc3e::rc2f::Controller::new(clock, &ids);
    let r = Bencher::new(10, 2000).run("gcs read (wall, ex-model)", || {
        c.gcs_read(rc3e::rc2f::controller::gcs_reg::STATUS).unwrap()
    });
    println!("{}", r.line());
    report.record("hotpath.gcs_read", &r);
}

fn main() {
    rc3e::util::logging::init();
    println!("L3 hot-path microbenches (wall time)\n");
    let out = baseline::out_path();
    let mut report = match &out {
        Some(p) => BaselineReport::load_or_new(p),
        None => BaselineReport::new(),
    };
    bench_engine(&mut report);
    bench_fifo(&mut report);
    bench_arbiter(&mut report);
    bench_json(&mut report);
    bench_rpc(&mut report);
    bench_controller(&mut report);
    if let Some(p) = &out {
        report.save(p).unwrap();
        println!("\nbaseline series written to {}", p.display());
    }
    println!("\nhotpath OK");
}
