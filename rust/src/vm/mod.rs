//! Virtual-machine allocation extension (RSaaS).
//!
//! Section IV-C: "we integrated the allocation of user-specific
//! virtual machines with direct access to allocated FPGAs as an
//! extension of the RSaaS service model." And III-A: "For hardware
//! interface and driver development fully virtual machines with the
//! necessary FPGA devices attached are allocatable by users."
//!
//! The VM manager models boot/shutdown with virtual-time charges and
//! tracks PCI passthrough of the allocated device. The interesting
//! system behaviour — an FPGA passed into a VM is invisible to the
//! host middleware until the VM is gone — is enforced here.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::sched::{AdmissionRequest, Lease, RequestClass, Scheduler};
use crate::util::clock::{VirtualClock, VirtualTime};
use crate::util::ids::{AllocationId, FpgaId, LeaseToken, UserId, VmId};

/// Modeled VM boot time (cloud-image boot + driver probe).
pub const VM_BOOT_S: f64 = 18.0;
/// Modeled VM shutdown time.
pub const VM_SHUTDOWN_S: f64 = 4.0;

/// VM lifecycle state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VmState {
    Booting,
    Running,
    Stopped,
}

/// One user VM with a passed-through FPGA.
#[derive(Debug, Clone)]
pub struct VmRecord {
    pub id: VmId,
    pub user: UserId,
    pub fpga: FpgaId,
    pub allocation: AllocationId,
    /// Capability token of the scheduler lease backing the
    /// passthrough device.
    pub lease: LeaseToken,
    pub state: VmState,
    /// Memory assigned (GiB) — bookkeeping for the node.
    pub mem_gib: u64,
    pub vcpus: u64,
}

/// VM manager errors.
#[derive(Debug, thiserror::Error)]
pub enum VmError {
    #[error("hypervisor: {0}")]
    Hypervisor(#[from] HypervisorError),
    #[error("vm {0} not found")]
    NotFound(VmId),
    #[error("vm {0} is not running")]
    NotRunning(VmId),
}

/// The VM extension over the hypervisor. Device admission goes
/// through the cluster scheduler like every other allocation.
pub struct VmManager {
    hv: Arc<Hypervisor>,
    sched: Arc<Scheduler>,
    clock: Arc<VirtualClock>,
    vms: Mutex<BTreeMap<VmId, VmRecord>>,
    /// Armed lease handles, released on destroy (kept out of
    /// `VmRecord` so records stay cloneable for listings).
    leases: Mutex<BTreeMap<VmId, Lease>>,
}

impl VmManager {
    pub fn new(hv: Arc<Hypervisor>) -> VmManager {
        let sched = Scheduler::new(Arc::clone(&hv));
        VmManager::with_scheduler(sched)
    }

    /// Share the cluster scheduler (tenant quotas then cover VM
    /// passthrough devices too).
    pub fn with_scheduler(sched: Arc<Scheduler>) -> VmManager {
        let hv = Arc::clone(sched.hv());
        let clock = Arc::clone(&hv.clock);
        VmManager {
            hv,
            sched,
            clock,
            vms: Mutex::new(BTreeMap::new()),
            leases: Mutex::new(BTreeMap::new()),
        }
    }

    /// Allocate a physical FPGA and boot a VM with it passed through.
    pub fn launch(
        &self,
        user: UserId,
        vcpus: u64,
        mem_gib: u64,
    ) -> Result<VmRecord, VmError> {
        let vm_id = VmId(self.hv.db.lock().unwrap().vm_ids.next());
        let lease = self
            .sched
            .admit(
                &AdmissionRequest::physical(user, RequestClass::Interactive)
                    .vm(vm_id),
            )
            .map_err(HypervisorError::from)?;
        let allocation = lease.alloc();
        let fpga = lease.fpga().expect("fresh physical lease placed");
        let mut record = VmRecord {
            id: vm_id,
            user,
            fpga,
            allocation,
            lease: lease.token(),
            state: VmState::Booting,
            mem_gib,
            vcpus,
        };
        self.vms.lock().unwrap().insert(vm_id, record.clone());
        self.leases.lock().unwrap().insert(vm_id, lease);
        // Boot charge, then running.
        self.clock.advance(VirtualTime::from_secs_f64(VM_BOOT_S));
        record.state = VmState::Running;
        self.vms.lock().unwrap().insert(vm_id, record.clone());
        Ok(record)
    }

    /// The device is reachable from inside the VM only.
    pub fn passthrough_visible(&self, vm: VmId) -> Result<FpgaId, VmError> {
        let vms = self.vms.lock().unwrap();
        let rec = vms.get(&vm).ok_or(VmError::NotFound(vm))?;
        if rec.state != VmState::Running {
            return Err(VmError::NotRunning(vm));
        }
        Ok(rec.fpga)
    }

    /// Shut down: stop the VM, release the FPGA lease back to the
    /// cloud.
    pub fn destroy(&self, vm: VmId) -> Result<(), VmError> {
        {
            let mut vms = self.vms.lock().unwrap();
            let rec = vms.get_mut(&vm).ok_or(VmError::NotFound(vm))?;
            rec.state = VmState::Stopped;
        }
        self.clock
            .advance(VirtualTime::from_secs_f64(VM_SHUTDOWN_S));
        let lease = self.leases.lock().unwrap().remove(&vm);
        if let Some(lease) = lease {
            lease.release().map_err(HypervisorError::from)?;
        }
        self.vms.lock().unwrap().remove(&vm);
        Ok(())
    }

    pub fn list(&self, user: Option<UserId>) -> Vec<VmRecord> {
        self.vms
            .lock()
            .unwrap()
            .values()
            .filter(|v| user.map(|u| v.user == u).unwrap_or(true))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ServiceModel};
    use crate::hypervisor::PlacementPolicy;

    fn manager() -> VmManager {
        let hv = Arc::new(
            Hypervisor::boot(
                &ClusterConfig::single_vc707(),
                VirtualClock::new(),
                PlacementPolicy::ConsolidateFirst,
            )
            .unwrap(),
        );
        VmManager::new(hv)
    }

    #[test]
    fn launch_boots_and_passes_device_through() {
        let m = manager();
        let user = m.hv.add_user("dev");
        let t0 = m.clock.now();
        let vm = m.launch(user, 4, 8).unwrap();
        assert_eq!(vm.state, VmState::Running);
        assert!(m.clock.since(t0).as_secs_f64() >= VM_BOOT_S);
        assert_eq!(m.passthrough_visible(vm.id).unwrap(), vm.fpga);
    }

    #[test]
    fn vm_holds_exclusive_device() {
        let m = manager();
        let user = m.hv.add_user("dev");
        let _vm = m.launch(user, 2, 4).unwrap();
        // The only device is inside the VM: no vFPGA or physical
        // allocation can happen.
        assert!(m.hv.alloc_vfpga(user, ServiceModel::RAaaS).is_err());
        assert!(m.hv.alloc_physical(user, None).is_err());
    }

    #[test]
    fn destroy_returns_device_to_cloud() {
        let m = manager();
        let user = m.hv.add_user("dev");
        let vm = m.launch(user, 2, 4).unwrap();
        m.destroy(vm.id).unwrap();
        assert!(m.list(None).is_empty());
        // Device is allocatable again.
        assert!(m.hv.alloc_vfpga(user, ServiceModel::RAaaS).is_ok());
    }

    #[test]
    fn stopped_vm_hides_device() {
        let m = manager();
        let user = m.hv.add_user("dev");
        let vm = m.launch(user, 2, 4).unwrap();
        m.destroy(vm.id).unwrap();
        assert!(matches!(
            m.passthrough_visible(vm.id),
            Err(VmError::NotFound(_))
        ));
    }

    #[test]
    fn list_filters_by_user() {
        let m = manager();
        let a = m.hv.add_user("a");
        let _vm = m.launch(a, 1, 2).unwrap();
        let b = m.hv.add_user("b");
        assert_eq!(m.list(Some(a)).len(), 1);
        assert_eq!(m.list(Some(b)).len(), 0);
        assert_eq!(m.list(None).len(), 1);
    }

    #[test]
    fn capacity_limits_vms() {
        let m = manager();
        let user = m.hv.add_user("dev");
        m.launch(user, 1, 1).unwrap();
        assert!(matches!(
            m.launch(user, 1, 1),
            Err(VmError::Hypervisor(HypervisorError::NoCapacity))
        ));
    }
}
