//! Virtual clock for hardware-latency simulation.
//!
//! The paper's latencies span five orders of magnitude (0.2 ms ucs
//! access → 28 s JTAG configuration). Replaying them in wall-clock
//! would make the test suite unusable, so every hardware-timed
//! operation charges its duration to a [`VirtualClock`] instead.
//!
//! A clock can optionally *sleep* a scaled-down fraction of the charged
//! time (`TimeScale`), which the benches use to recover realistic
//! concurrency interleavings, while unit tests run with pure
//! accounting (scale = 0 ⇒ never sleeps).
//!
//! The clock is shared (`Arc` + atomics) because vFPGA cores charge it
//! from worker threads concurrently; `advance_max` implements the
//! "parallel section" rule: concurrent hardware operations overlap, so
//! the clock moves to the max end-time, not the sum.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Nanosecond-resolution virtual time point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct VirtualTime(pub u64);

impl VirtualTime {
    pub const ZERO: VirtualTime = VirtualTime(0);

    pub fn from_secs_f64(s: f64) -> VirtualTime {
        VirtualTime((s * 1e9) as u64)
    }
    pub fn from_millis_f64(ms: f64) -> VirtualTime {
        VirtualTime((ms * 1e6) as u64)
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn saturating_sub(self, other: VirtualTime) -> VirtualTime {
        VirtualTime(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for VirtualTime {
    type Output = VirtualTime;
    fn add(self, rhs: VirtualTime) -> VirtualTime {
        VirtualTime(self.0 + rhs.0)
    }
}

impl std::fmt::Display for VirtualTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ms = self.as_millis_f64();
        if ms >= 1000.0 {
            write!(f, "{:.3} s", ms / 1000.0)
        } else {
            write!(f, "{ms:.3} ms")
        }
    }
}

/// Shared monotonically-advancing virtual clock.
///
/// `scale_denominator` controls optional wall-clock sleeping:
/// * `0` — pure accounting, never sleeps (unit tests);
/// * `n > 0` — sleeps `charged / n` wall time (benches use e.g. 1000
///   so a simulated 28 s JTAG configuration costs 28 ms of real time,
///   preserving interleavings without the wait).
#[derive(Debug)]
pub struct VirtualClock {
    now_ns: AtomicU64,
    scale_denominator: u64,
}

impl VirtualClock {
    /// Pure-accounting clock (never sleeps).
    pub fn new() -> Arc<VirtualClock> {
        Arc::new(VirtualClock {
            now_ns: AtomicU64::new(0),
            scale_denominator: 0,
        })
    }

    /// Clock that also sleeps `charged / denominator` of wall time.
    pub fn with_scale(denominator: u64) -> Arc<VirtualClock> {
        Arc::new(VirtualClock {
            now_ns: AtomicU64::new(0),
            scale_denominator: denominator,
        })
    }

    /// Current virtual time.
    pub fn now(&self) -> VirtualTime {
        VirtualTime(self.now_ns.load(Ordering::SeqCst))
    }

    /// Charge a *serial* duration: the clock advances by `d`.
    pub fn advance(&self, d: VirtualTime) -> VirtualTime {
        self.maybe_sleep(d);
        VirtualTime(self.now_ns.fetch_add(d.0, Ordering::SeqCst) + d.0)
    }

    /// Charge a *parallel* duration: the clock advances to at least
    /// `start + d`. Concurrent operations that overlap in hardware
    /// (e.g. four cores streaming simultaneously) each call this with
    /// their own start; the clock lands on the max end-time.
    pub fn advance_max(&self, start: VirtualTime, d: VirtualTime) {
        self.maybe_sleep(d);
        let end = start.0 + d.0;
        self.now_ns.fetch_max(end, Ordering::SeqCst);
    }

    /// Elapsed virtual time since `start`.
    pub fn since(&self, start: VirtualTime) -> VirtualTime {
        self.now().saturating_sub(start)
    }

    fn maybe_sleep(&self, d: VirtualTime) {
        if self.scale_denominator > 0 {
            let ns = d.0 / self.scale_denominator;
            if ns > 0 {
                std::thread::sleep(Duration::from_nanos(ns));
            }
        }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock {
            now_ns: AtomicU64::new(0),
            scale_denominator: 0,
        }
    }
}

/// A stopwatch over a virtual clock: measures charged time in a scope.
pub struct VirtualStopwatch {
    clock: Arc<VirtualClock>,
    start: VirtualTime,
}

impl VirtualStopwatch {
    pub fn start(clock: &Arc<VirtualClock>) -> VirtualStopwatch {
        VirtualStopwatch {
            clock: Arc::clone(clock),
            start: clock.now(),
        }
    }

    pub fn elapsed(&self) -> VirtualTime {
        self.clock.since(self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversion_roundtrip() {
        let t = VirtualTime::from_millis_f64(28_370.0);
        assert!((t.as_secs_f64() - 28.37).abs() < 1e-9);
        assert_eq!(VirtualTime::from_secs_f64(0.5).as_millis_f64(), 500.0);
    }

    #[test]
    fn advance_is_cumulative() {
        let c = VirtualClock::new();
        c.advance(VirtualTime::from_millis_f64(11.0));
        c.advance(VirtualTime::from_millis_f64(80.0));
        assert!((c.now().as_millis_f64() - 91.0).abs() < 1e-6);
    }

    #[test]
    fn advance_max_models_overlap() {
        let c = VirtualClock::new();
        let start = c.now();
        // Four concurrent 1 s operations overlap: clock moves 1 s, not 4.
        for _ in 0..4 {
            c.advance_max(start, VirtualTime::from_secs_f64(1.0));
        }
        assert!((c.now().as_secs_f64() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn advance_max_monotone() {
        let c = VirtualClock::new();
        c.advance(VirtualTime::from_secs_f64(5.0));
        // A parallel op that would end before `now` must not rewind.
        c.advance_max(VirtualTime::ZERO, VirtualTime::from_secs_f64(1.0));
        assert!((c.now().as_secs_f64() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn stopwatch_measures_span() {
        let c = VirtualClock::new();
        c.advance(VirtualTime::from_millis_f64(3.0));
        let sw = VirtualStopwatch::start(&c);
        c.advance(VirtualTime::from_millis_f64(7.0));
        assert!((sw.elapsed().as_millis_f64() - 7.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_advance_max() {
        let c = VirtualClock::new();
        let start = c.now();
        let hs: Vec<_> = (1..=8)
            .map(|i| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    c.advance_max(
                        start,
                        VirtualTime::from_millis_f64(i as f64 * 10.0),
                    );
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert!((c.now().as_millis_f64() - 80.0).abs() < 1e-6);
    }

    #[test]
    fn display_picks_unit() {
        assert_eq!(
            format!("{}", VirtualTime::from_millis_f64(732.0)),
            "732.000 ms"
        );
        assert_eq!(
            format!("{}", VirtualTime::from_secs_f64(28.37)),
            "28.370 s"
        );
    }
}
