//! Typed identifiers for the cloud's entities.
//!
//! Everything the hypervisor tracks — nodes, physical FPGAs, vFPGA
//! regions, allocations, users, jobs, VMs — gets a newtype id so the
//! device-database code cannot mix them up. Ids render as
//! `prefix-<n>` for logs and the CLI.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

macro_rules! typed_id {
    ($(#[$doc:meta])* $name:ident, $prefix:expr) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
        pub struct $name(pub u64);

        impl $name {
            /// Id prefix used in display / parsing.
            pub const PREFIX: &'static str = $prefix;

            /// Parse from the `prefix-<n>` display form.
            pub fn parse(s: &str) -> Option<$name> {
                let rest = s.strip_prefix($prefix)?.strip_prefix('-')?;
                rest.parse().ok().map($name)
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{}-{}", $prefix, self.0)
            }
        }
    };
}

typed_id!(
    /// A cluster node (host machine with FPGAs attached).
    NodeId,
    "node"
);
typed_id!(
    /// A physical FPGA board.
    FpgaId,
    "fpga"
);
typed_id!(
    /// A virtual FPGA region on a physical device.
    VfpgaId,
    "vfpga"
);
typed_id!(
    /// A resource allocation (lease) held by a user.
    AllocationId,
    "alloc"
);
typed_id!(
    /// A registered cloud user.
    UserId,
    "user"
);
typed_id!(
    /// A batch job.
    JobId,
    "job"
);
typed_id!(
    /// A virtual machine (RSaaS extension).
    VmId,
    "vm"
);
typed_id!(
    /// A queued admission request in the cluster scheduler.
    TicketId,
    "ticket"
);
typed_id!(
    /// A time-boxed capacity reservation in the cluster scheduler.
    ReservationId,
    "rsv"
);
typed_id!(
    /// A request trace in the flight recorder (`util::trace`).
    TraceId,
    "trace"
);
typed_id!(
    /// A single span within a trace.
    SpanId,
    "span"
);

impl TraceId {
    /// Mint a client-side trace id from OS entropy mixed with a
    /// process-wide counter. Server-minted ids are small sequential
    /// numbers; client-minted ones live in the full 64-bit space so
    /// independent clients joining the same flight recorder do not
    /// collide.
    pub fn mint() -> TraceId {
        use std::collections::hash_map::RandomState;
        use std::hash::{BuildHasher, Hasher};
        static SALT: AtomicU64 = AtomicU64::new(0x7ACE);
        let mut h = RandomState::new().build_hasher();
        h.write_u64(SALT.fetch_add(1, Ordering::Relaxed));
        TraceId(h.finish())
    }
}

/// Unguessable capability token for a scheduler lease.
///
/// Unlike the sequential [`typed ids`](AllocationId) above, a lease
/// token is 128 bits drawn from per-process OS entropy (via
/// `RandomState`) mixed with a process-wide counter — holding the
/// token *is* the authorization to operate on the lease, so it must
/// not be enumerable the way `alloc-<n>` is. Renders as
/// `lt-<32 hex digits>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LeaseToken(pub u128);

impl LeaseToken {
    pub const PREFIX: &'static str = "lt";

    /// Mint a fresh token. Two `RandomState`s contribute OS-seeded
    /// entropy; the counter guarantees process-local uniqueness even
    /// if the entropy source were degenerate.
    pub fn mint() -> LeaseToken {
        use std::collections::hash_map::RandomState;
        use std::hash::{BuildHasher, Hasher};
        static SALT: AtomicU64 = AtomicU64::new(0x5EED);
        let hi = RandomState::new().build_hasher().finish();
        let mut lo_hasher = RandomState::new().build_hasher();
        lo_hasher.write_u64(SALT.fetch_add(1, Ordering::Relaxed));
        let lo = lo_hasher.finish();
        LeaseToken(((hi as u128) << 64) | lo as u128)
    }

    /// Parse from the `lt-<hex>` display form.
    pub fn parse(s: &str) -> Option<LeaseToken> {
        let rest = s.strip_prefix("lt-")?;
        if rest.is_empty() || rest.len() > 32 {
            return None;
        }
        u128::from_str_radix(rest, 16).ok().map(LeaseToken)
    }
}

impl fmt::Display for LeaseToken {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lt-{:032x}", self.0)
    }
}

/// Monotonic id generator (process-wide unique within a type).
#[derive(Debug, Default)]
pub struct IdGen {
    next: AtomicU64,
}

impl IdGen {
    pub fn new() -> IdGen {
        IdGen {
            next: AtomicU64::new(0),
        }
    }

    /// Start from an explicit floor (database reload).
    pub fn starting_at(n: u64) -> IdGen {
        IdGen {
            next: AtomicU64::new(n),
        }
    }

    pub fn next(&self) -> u64 {
        self.next.fetch_add(1, Ordering::SeqCst)
    }

    /// Raise the floor so reloaded ids are never reissued.
    pub fn bump_past(&self, seen: u64) {
        self.next.fetch_max(seen + 1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_parse_roundtrip() {
        let id = VfpgaId(7);
        assert_eq!(id.to_string(), "vfpga-7");
        assert_eq!(VfpgaId::parse("vfpga-7"), Some(id));
        assert_eq!(VfpgaId::parse("fpga-7"), None);
        assert_eq!(VfpgaId::parse("vfpga-x"), None);
        assert_eq!(VfpgaId::parse("vfpga7"), None);
    }

    #[test]
    fn idgen_monotonic_and_bumpable() {
        let g = IdGen::new();
        assert_eq!(g.next(), 0);
        assert_eq!(g.next(), 1);
        g.bump_past(10);
        assert_eq!(g.next(), 11);
        g.bump_past(5); // lower floor is a no-op
        assert_eq!(g.next(), 12);
    }

    #[test]
    fn lease_tokens_mint_unique_and_roundtrip() {
        let a = LeaseToken::mint();
        let b = LeaseToken::mint();
        assert_ne!(a, b, "two minted tokens collide");
        assert_eq!(LeaseToken::parse(&a.to_string()), Some(a));
        assert_eq!(LeaseToken::parse("lt-zz"), None);
        assert_eq!(LeaseToken::parse("alloc-3"), None);
        assert_eq!(LeaseToken::parse("lt-"), None);
        // Display is fixed-width hex.
        assert_eq!(a.to_string().len(), "lt-".len() + 32);
    }

    #[test]
    fn ids_are_distinct_types() {
        // Compile-time property; just exercise Display uniqueness.
        assert_ne!(NodeId(1).to_string(), FpgaId(1).to_string());
        assert_ne!(JobId(1).to_string(), VmId(1).to_string());
    }
}
