//! Heap-allocation probe for zero-copy assertions.
//!
//! The data-plane rework (see `docs/DATAPLANE.md`) promises that the
//! steady-state stream loop performs **zero heap allocations per
//! chunk**. A promise like that rots instantly without a test, so the
//! crate installs [`CountingAllocator`] as the global allocator: a
//! pass-through wrapper over [`System`] that bumps a *thread-local*
//! counter on every allocation. Tests snapshot
//! [`thread_allocations`] around a hot loop and assert the delta.
//!
//! Thread-local counting keeps the probe deterministic under the
//! parallel test runner — other threads' allocations never leak into
//! a measurement — and makes the read path a plain `Cell` access, so
//! the probe adds no contention to the allocator itself.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Pass-through global allocator counting allocations per thread.
pub struct CountingAllocator;

#[inline]
fn bump() {
    // `try_with` sidesteps recursion during thread-local init and
    // the teardown window where the key is already destroyed.
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

// SAFETY: every method delegates directly to `System`, which upholds
// the `GlobalAlloc` contract; the counter bump touches no allocator
// state.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Heap allocations performed by the *calling thread* so far.
///
/// Only deltas are meaningful:
/// ```
/// let before = rc3e::util::memprobe::thread_allocations();
/// // ... hot loop ...
/// let during = rc3e::util::memprobe::thread_allocations() - before;
/// assert_eq!(during, 0);
/// ```
pub fn thread_allocations() -> u64 {
    THREAD_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_allocations_on_this_thread() {
        let before = thread_allocations();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_allocations();
        assert!(after > before, "allocation not observed");
        drop(v);
    }

    #[test]
    fn no_alloc_loop_measures_zero() {
        let mut acc = 0u64;
        let before = thread_allocations();
        for i in 0..1000u64 {
            acc = acc.wrapping_add(i);
        }
        let after = thread_allocations();
        assert_eq!(after - before, 0);
        assert!(acc > 0);
    }

    #[test]
    fn other_threads_do_not_perturb_counter() {
        let before = thread_allocations();
        std::thread::spawn(|| {
            let _big: Vec<u8> = vec![0; 4096];
        })
        .join()
        .unwrap();
        // The spawned thread allocated; this thread's counter may
        // move only from the join machinery, not the vec. Assert the
        // delta is tiny rather than exactly zero to stay robust.
        let delta = thread_allocations() - before;
        assert!(delta < 16, "delta {delta}");
    }
}
