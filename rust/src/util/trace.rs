//! Request tracing and the flight recorder.
//!
//! Every RPC opens a *root span*; the layers it crosses (scheduler
//! admission, hypervisor programming, fpga reconfiguration, rc2f
//! streaming) open *child spans* around their expensive sections, so
//! one trace shows where a `program_full` spent its time — queue wait
//! vs quiesce vs partial reconfiguration vs DMA. Span timestamps come
//! from the attached [`VirtualClock`], the same clock the simulated
//! hardware charges, so durations line up with the model.
//!
//! The [`Tracer`] keeps the last [`Tracer::MAX_TRACES`] traces in a
//! bounded ring — the **flight recorder** — so recent requests are
//! always reconstructable post-hoc via the `trace_get` RPC or
//! `rc3e trace <id>`.
//!
//! Propagation is by ambient context, not plumbed parameters: opening
//! a span pushes a thread-local frame, and [`span`] attaches to
//! whatever frame is on top. Deep layers therefore need no signature
//! changes, and code running outside any request (unit tests, boot)
//! records nothing — [`span`] hands back an inert guard. Async job
//! workers re-establish context on their own thread by capturing
//! [`current`] at submit time and calling [`TraceContext::adopt`].

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::clock::{VirtualClock, VirtualTime};
use crate::util::ids::{IdGen, SpanId, TraceId};

/// How a span ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Still open (or its thread died without dropping the guard).
    Open,
    Ok,
    Error(String),
}

impl SpanOutcome {
    pub fn label(&self) -> &str {
        match self {
            SpanOutcome::Open => "open",
            SpanOutcome::Ok => "ok",
            SpanOutcome::Error(_) => "error",
        }
    }
}

/// One recorded span: a named, timed section of a trace.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub id: SpanId,
    pub parent: Option<SpanId>,
    pub name: String,
    pub start: VirtualTime,
    pub end: Option<VirtualTime>,
    pub attrs: Vec<(String, String)>,
    pub outcome: SpanOutcome,
}

impl SpanRecord {
    /// Duration if closed, else time still unaccounted (zero).
    pub fn duration(&self) -> VirtualTime {
        match self.end {
            Some(e) => e.saturating_sub(self.start),
            None => VirtualTime::ZERO,
        }
    }
}

/// A finished (or in-flight) trace pulled out of the recorder.
#[derive(Debug, Clone)]
pub struct TraceSnapshot {
    pub trace: TraceId,
    /// Spans in open order; the first is the root.
    pub spans: Vec<SpanRecord>,
    /// Spans dropped past the per-trace cap.
    pub truncated: u64,
}

struct TraceBuf {
    spans: Vec<SpanRecord>,
    truncated: u64,
}

#[derive(Default)]
struct Recorder {
    traces: BTreeMap<TraceId, TraceBuf>,
    /// Insertion order for ring eviction (oldest at the front).
    order: VecDeque<TraceId>,
}

/// Per-server span recorder with a bounded trace ring.
///
/// Lock-cheap: recording takes one short mutex hold per span open /
/// close; code outside a trace context never touches the lock at all.
pub struct Tracer {
    clock: Arc<VirtualClock>,
    enabled: AtomicBool,
    trace_ids: IdGen,
    span_ids: IdGen,
    recorder: Mutex<Recorder>,
}

struct ContextFrame {
    tracer: Arc<Tracer>,
    trace: TraceId,
    span: SpanId,
}

thread_local! {
    static CONTEXT: RefCell<Vec<ContextFrame>> = const { RefCell::new(Vec::new()) };
}

impl Tracer {
    /// Flight-recorder depth: traces retained before ring eviction.
    pub const MAX_TRACES: usize = 128;
    /// Spans retained per trace; extras are counted, not stored.
    pub const MAX_SPANS_PER_TRACE: usize = 256;

    pub fn new(clock: Arc<VirtualClock>) -> Arc<Tracer> {
        Arc::new(Tracer {
            clock,
            enabled: AtomicBool::new(true),
            trace_ids: IdGen::new(),
            span_ids: IdGen::new(),
            recorder: Mutex::new(Recorder::default()),
        })
    }

    /// Turn recording on/off (benches measure the off cost).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::SeqCst);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::SeqCst)
    }

    /// Open a root span for an inbound request.
    ///
    /// With a `hint` naming a trace the recorder already holds, the
    /// new span joins that trace as a child of its root — a client
    /// that stamps one trace id across `alloc` → `program` → `stream`
    /// gets a single connected tree. An unknown hint starts a fresh
    /// trace under the client-minted id; no hint mints a server id.
    pub fn root(
        self: &Arc<Self>,
        name: &str,
        hint: Option<TraceId>,
    ) -> SpanGuard {
        if !self.is_enabled() {
            return SpanGuard { active: None };
        }
        let span = SpanId(self.span_ids.next());
        let (trace, parent) = {
            let mut rec = self.recorder.lock().unwrap();
            match hint {
                Some(t) if rec.traces.contains_key(&t) => {
                    let root = rec.traces[&t].spans.first().map(|s| s.id);
                    (t, root)
                }
                Some(t) => {
                    rec.open_trace(t);
                    (t, None)
                }
                None => {
                    let t = TraceId(self.trace_ids.next());
                    rec.open_trace(t);
                    (t, None)
                }
            }
        };
        self.open(trace, parent, span, name)
    }

    /// All trace ids currently in the recorder, newest first.
    pub fn recent(&self) -> Vec<TraceId> {
        let rec = self.recorder.lock().unwrap();
        rec.order.iter().rev().copied().collect()
    }

    pub fn contains(&self, trace: TraceId) -> bool {
        self.recorder.lock().unwrap().traces.contains_key(&trace)
    }

    /// Copy a trace out of the recorder.
    pub fn snapshot(&self, trace: TraceId) -> Option<TraceSnapshot> {
        let rec = self.recorder.lock().unwrap();
        rec.traces.get(&trace).map(|buf| TraceSnapshot {
            trace,
            spans: buf.spans.clone(),
            truncated: buf.truncated,
        })
    }

    fn open(
        self: &Arc<Self>,
        trace: TraceId,
        parent: Option<SpanId>,
        span: SpanId,
        name: &str,
    ) -> SpanGuard {
        let start = self.clock.now();
        let recorded = {
            let mut rec = self.recorder.lock().unwrap();
            match rec.traces.get_mut(&trace) {
                Some(buf) if buf.spans.len() < Self::MAX_SPANS_PER_TRACE => {
                    buf.spans.push(SpanRecord {
                        id: span,
                        parent,
                        name: name.to_string(),
                        start,
                        end: None,
                        attrs: Vec::new(),
                        outcome: SpanOutcome::Open,
                    });
                    true
                }
                Some(buf) => {
                    buf.truncated += 1;
                    false
                }
                // Trace evicted from the ring while still in flight.
                None => false,
            }
        };
        if !recorded {
            return SpanGuard { active: None };
        }
        CONTEXT.with(|c| {
            c.borrow_mut().push(ContextFrame {
                tracer: Arc::clone(self),
                trace,
                span,
            })
        });
        SpanGuard {
            active: Some(ActiveSpan {
                tracer: Arc::clone(self),
                trace,
                span,
                failed: Mutex::new(None),
            }),
        }
    }

    fn with_span<F: FnOnce(&mut SpanRecord)>(
        &self,
        trace: TraceId,
        span: SpanId,
        f: F,
    ) {
        let mut rec = self.recorder.lock().unwrap();
        if let Some(buf) = rec.traces.get_mut(&trace) {
            if let Some(s) = buf.spans.iter_mut().find(|s| s.id == span) {
                f(s);
            }
        }
    }
}

impl Recorder {
    fn open_trace(&mut self, trace: TraceId) {
        while self.order.len() >= Tracer::MAX_TRACES {
            if let Some(old) = self.order.pop_front() {
                self.traces.remove(&old);
            }
        }
        self.order.push_back(trace);
        self.traces.insert(
            trace,
            TraceBuf {
                spans: Vec::new(),
                truncated: 0,
            },
        );
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.is_enabled())
            .finish()
    }
}

struct ActiveSpan {
    tracer: Arc<Tracer>,
    trace: TraceId,
    span: SpanId,
    /// Error message set by [`SpanGuard::fail`], applied at drop.
    failed: Mutex<Option<String>>,
}

/// RAII handle for an open span; closing happens on drop.
///
/// An inert guard (no active span) is returned when tracing is off or
/// no context is established — every method is then a no-op, so call
/// sites never branch on "is tracing on".
pub struct SpanGuard {
    active: Option<ActiveSpan>,
}

impl SpanGuard {
    /// Attach a key=value attribute to the span.
    pub fn attr(&self, key: &str, value: impl ToString) {
        if let Some(a) = &self.active {
            let v = value.to_string();
            a.tracer.with_span(a.trace, a.span, |s| {
                s.attrs.push((key.to_string(), v));
            });
        }
    }

    /// Mark the span failed; recorded as the outcome at drop.
    pub fn fail(&self, error: impl ToString) {
        if let Some(a) = &self.active {
            *a.failed.lock().unwrap() = Some(error.to_string());
        }
    }

    /// Trace this span belongs to (None for an inert guard).
    pub fn trace_id(&self) -> Option<TraceId> {
        self.active.as_ref().map(|a| a.trace)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.active.take() else { return };
        // Pop our context frame. Guards drop in LIFO scope order on
        // one thread, so ours is the top — but scan defensively in
        // case an intermediate guard was leaked.
        CONTEXT.with(|c| {
            let mut frames = c.borrow_mut();
            if let Some(i) = frames.iter().rposition(|f| f.span == a.span) {
                frames.truncate(i);
            }
        });
        let end = a.tracer.clock.now();
        let outcome = match a.failed.lock().unwrap().take() {
            Some(e) => SpanOutcome::Error(e),
            None => SpanOutcome::Ok,
        };
        a.tracer.with_span(a.trace, a.span, |s| {
            s.end = Some(end);
            s.outcome = outcome;
        });
    }
}

impl std::fmt::Debug for SpanGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpanGuard")
            .field("active", &self.active.is_some())
            .finish()
    }
}

/// Open a child span under the current thread's context.
///
/// Inert (records nothing) when no span is open on this thread, so
/// library layers call it unconditionally.
pub fn span(name: &str) -> SpanGuard {
    let frame = CONTEXT.with(|c| {
        c.borrow().last().map(|f| {
            (Arc::clone(&f.tracer), f.trace, f.span)
        })
    });
    match frame {
        Some((tracer, trace, parent)) => {
            let id = SpanId(tracer.span_ids.next());
            tracer.open(trace, Some(parent), id, name)
        }
        None => SpanGuard { active: None },
    }
}

/// Capture the current thread's trace context for handoff to another
/// thread (async job workers adopt the submitter's trace).
pub fn current() -> Option<TraceContext> {
    CONTEXT.with(|c| {
        c.borrow().last().map(|f| TraceContext {
            tracer: Arc::clone(&f.tracer),
            trace: f.trace,
            span: f.span,
        })
    })
}

/// A captured trace position, re-attachable on another thread.
#[derive(Clone)]
pub struct TraceContext {
    tracer: Arc<Tracer>,
    trace: TraceId,
    span: SpanId,
}

impl TraceContext {
    pub fn trace(&self) -> TraceId {
        self.trace
    }

    /// Open a span parented at the captured position and make it the
    /// current context on *this* thread for the guard's lifetime.
    pub fn adopt(&self, name: &str) -> SpanGuard {
        if !self.tracer.is_enabled() {
            return SpanGuard { active: None };
        }
        let id = SpanId(self.tracer.span_ids.next());
        self.tracer.open(self.trace, Some(self.span), id, name)
    }
}

impl std::fmt::Debug for TraceContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceContext({}, {})", self.trace, self.span)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer() -> Arc<Tracer> {
        Tracer::new(VirtualClock::new())
    }

    #[test]
    fn root_and_children_form_a_tree() {
        let t = tracer();
        let clock = Arc::clone(&t.clock);
        let root = t.root("rpc.program_full", None);
        let trace = root.trace_id().unwrap();
        clock.advance(VirtualTime::from_millis_f64(2.0));
        {
            let _admit = span("sched.admit");
            clock.advance(VirtualTime::from_millis_f64(5.0));
            {
                let q = span("sched.quota");
                q.attr("tenant", "user-1");
            }
        }
        root.attr("method", "program_full");
        drop(root);
        let snap = t.snapshot(trace).unwrap();
        assert_eq!(snap.spans.len(), 3);
        assert_eq!(snap.spans[0].name, "rpc.program_full");
        assert_eq!(snap.spans[0].parent, None);
        assert_eq!(snap.spans[1].parent, Some(snap.spans[0].id));
        assert_eq!(snap.spans[2].parent, Some(snap.spans[1].id));
        assert_eq!(snap.spans[2].attrs, vec![(
            "tenant".to_string(),
            "user-1".to_string()
        )]);
        assert!(snap
            .spans
            .iter()
            .all(|s| s.outcome == SpanOutcome::Ok && s.end.is_some()));
        assert!(
            (snap.spans[1].duration().as_millis_f64() - 5.0).abs() < 1e-6
        );
    }

    #[test]
    fn no_context_means_inert_guard() {
        let g = span("orphan");
        assert!(g.trace_id().is_none());
        g.attr("k", "v"); // must not panic
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = tracer();
        t.set_enabled(false);
        let g = t.root("rpc.hello", None);
        assert!(g.trace_id().is_none());
        drop(g);
        assert!(t.recent().is_empty());
    }

    #[test]
    fn hint_joins_existing_trace_under_its_root() {
        let t = tracer();
        let first = t.root("rpc.vfpga_alloc", None);
        let trace = first.trace_id().unwrap();
        drop(first);
        let second = t.root("rpc.program_full", Some(trace));
        assert_eq!(second.trace_id(), Some(trace));
        drop(second);
        let snap = t.snapshot(trace).unwrap();
        assert_eq!(snap.spans.len(), 2);
        assert_eq!(snap.spans[1].parent, Some(snap.spans[0].id));
    }

    #[test]
    fn unknown_hint_starts_fresh_trace_with_that_id() {
        let t = tracer();
        let minted = TraceId::mint();
        let g = t.root("rpc.hello", Some(minted));
        assert_eq!(g.trace_id(), Some(minted));
        drop(g);
        assert_eq!(t.snapshot(minted).unwrap().spans[0].parent, None);
    }

    #[test]
    fn failed_span_records_error_outcome() {
        let t = tracer();
        let g = t.root("rpc.stream", None);
        let trace = g.trace_id().unwrap();
        g.fail("no such core");
        drop(g);
        let snap = t.snapshot(trace).unwrap();
        assert_eq!(
            snap.spans[0].outcome,
            SpanOutcome::Error("no such core".into())
        );
    }

    #[test]
    fn adopt_carries_context_across_threads() {
        let t = tracer();
        let root = t.root("rpc.job_submit", None);
        let trace = root.trace_id().unwrap();
        let ctx = current().expect("context set");
        assert_eq!(ctx.trace(), trace);
        let h = std::thread::spawn(move || {
            let _job = ctx.adopt("job.stream");
            let _child = span("rc2f.stream");
        });
        h.join().unwrap();
        drop(root);
        let snap = t.snapshot(trace).unwrap();
        let names: Vec<&str> =
            snap.spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["rpc.job_submit", "job.stream", "rc2f.stream"]);
        assert_eq!(snap.spans[1].parent, Some(snap.spans[0].id));
        assert_eq!(snap.spans[2].parent, Some(snap.spans[1].id));
    }

    #[test]
    fn ring_evicts_oldest_trace() {
        let t = tracer();
        let first = {
            let g = t.root("rpc.hello", None);
            g.trace_id().unwrap()
        };
        for _ in 0..Tracer::MAX_TRACES {
            drop(t.root("rpc.hello", None));
        }
        assert!(!t.contains(first), "oldest trace survived eviction");
        assert_eq!(t.recent().len(), Tracer::MAX_TRACES);
    }

    #[test]
    fn span_cap_truncates_not_grows() {
        let t = tracer();
        let root = t.root("rpc.batch", None);
        let trace = root.trace_id().unwrap();
        let mut guards = Vec::new();
        for i in 0..Tracer::MAX_SPANS_PER_TRACE + 10 {
            guards.push(span(&format!("step.{i}")));
        }
        guards.clear();
        drop(root);
        let snap = t.snapshot(trace).unwrap();
        assert_eq!(snap.spans.len(), Tracer::MAX_SPANS_PER_TRACE);
        assert_eq!(snap.truncated, 11);
    }
}
