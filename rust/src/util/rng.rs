//! Deterministic PRNG (xorshift64*) for workload generation and the
//! property-testing framework.
//!
//! Not cryptographic — used only for reproducible synthetic workloads
//! (matrix streams, arrival processes) and test-case generation. The
//! generator is seedable so every bench row and every property-test
//! failure is replayable from its printed seed.

/// xorshift64* — 64-bit state, passes BigCrush on the low 32 bits.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded constructor; a zero seed is remapped (xorshift cannot
    /// leave the all-zero state).
    pub fn new(seed: u64) -> Rng {
        Rng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`; bound must be non-zero.
    /// Uses Lemire's multiply-shift rejection method (unbiased).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let low = m as u64;
            if low >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
            // Rejected (probability < bound/2^64): retry.
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-scale, scale)` — matrix element generator.
    pub fn next_f32_sym(&mut self, scale: f32) -> f32 {
        (self.next_f64() as f32 * 2.0 - 1.0) * scale
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially-distributed inter-arrival sample with rate λ
    /// (events/sec) — Poisson arrival processes for the cloud
    /// workload generator.
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Choose an element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.next_below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Fill a buffer with f32 matrix elements (stream generator hot
    /// path — used to synthesize the 100k-matrix workloads).
    pub fn fill_f32(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.next_f32_sym(scale);
        }
    }

    /// Fork a child generator (stable derivation — lets parallel
    /// workers own independent streams from one seed).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn next_below_covers_all_residues() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.next_below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range(10, 13);
            assert!((10..=13).contains(&v));
            lo_seen |= v == 10;
            hi_seen |= v == 13;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn exp_has_correct_mean() {
        let mut r = Rng::new(13);
        let rate = 4.0;
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.next_exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fill_f32_within_scale() {
        let mut r = Rng::new(29);
        let mut buf = vec![0.0f32; 256];
        r.fill_f32(&mut buf, 2.0);
        assert!(buf.iter().all(|v| (-2.0..2.0).contains(v)));
        assert!(buf.iter().any(|&v| v != 0.0));
    }
}
