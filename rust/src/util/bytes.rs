//! Wire/byte-buffer helpers shared by the middleware protocol, the
//! bitstream container format and the PCIe DMA simulation.

/// Append a u32 little-endian.
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a u64 little-endian.
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a length-prefixed UTF-8 string (u32 length).
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Cursor for reading the encodings above, with range checks.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Error type for truncated/invalid reads.
#[derive(Debug, thiserror::Error)]
#[error("byte reader error: {0}")]
pub struct ReadError(pub String);

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], ReadError> {
        if self.remaining() < n {
            return Err(ReadError(format!(
                "need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32, ReadError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, ReadError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String, ReadError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ReadError("invalid utf-8 string".into()))
    }
}

/// View an f32 slice as bytes (no copy) — DMA buffers.
pub fn f32_as_bytes(data: &[f32]) -> &[u8] {
    // Safety: f32 has no invalid bit patterns and alignment of u8 is 1.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    }
}

/// Copy bytes into an f32 vec (handles the paper's 32-bit float
/// streaming payloads coming back from device files).
pub fn bytes_to_f32(bytes: &[u8]) -> Result<Vec<f32>, ReadError> {
    if bytes.len() % 4 != 0 {
        return Err(ReadError(format!(
            "byte length {} not a multiple of 4",
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ints_and_strings() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEADBEEF);
        put_u64(&mut buf, u64::MAX - 1);
        put_str(&mut buf, "vfpga-0");
        let mut r = Reader::new(&buf);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.str().unwrap(), "vfpga-0");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncated_reads_error() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 10);
        let mut r = Reader::new(&buf);
        assert!(r.str().is_err()); // claims 10 bytes, has 0
        let mut r2 = Reader::new(&buf[..2]);
        assert!(r2.u32().is_err());
    }

    #[test]
    fn f32_byte_views() {
        let data = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let bytes = f32_as_bytes(&data);
        assert_eq!(bytes.len(), 16);
        assert_eq!(bytes_to_f32(bytes).unwrap(), data);
    }

    #[test]
    fn bytes_to_f32_rejects_ragged() {
        assert!(bytes_to_f32(&[0, 0, 0]).is_err());
    }
}
