//! Wire/byte-buffer helpers shared by the middleware protocol, the
//! bitstream container format and the PCIe DMA simulation.

/// Append a u32 little-endian.
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a u64 little-endian.
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a length-prefixed UTF-8 string (u32 length).
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Cursor for reading the encodings above, with range checks.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Error type for truncated/invalid reads.
#[derive(Debug, thiserror::Error)]
#[error("byte reader error: {0}")]
pub struct ReadError(pub String);

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], ReadError> {
        if self.remaining() < n {
            return Err(ReadError(format!(
                "need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32, ReadError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, ReadError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String, ReadError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ReadError("invalid utf-8 string".into()))
    }
}

/// View an f32 slice as bytes (no copy) — DMA buffers.
pub fn f32_as_bytes(data: &[f32]) -> &[u8] {
    // Safety: f32 has no invalid bit patterns and alignment of u8 is 1.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    }
}

/// Copy bytes into an f32 vec (handles the paper's 32-bit float
/// streaming payloads coming back from device files).
pub fn bytes_to_f32(bytes: &[u8]) -> Result<Vec<f32>, ReadError> {
    if bytes.len() % 4 != 0 {
        return Err(ReadError(format!(
            "byte length {} not a multiple of 4",
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Decode bytes into a caller-owned f32 buffer without allocating
/// (beyond the buffer's first growth) — the data-plane core loop
/// reuses one scratch vec across every chunk.
pub fn bytes_to_f32_into(
    bytes: &[u8],
    out: &mut Vec<f32>,
) -> Result<(), ReadError> {
    if bytes.len() % 4 != 0 {
        return Err(ReadError(format!(
            "byte length {} not a multiple of 4",
            bytes.len()
        )));
    }
    out.clear();
    out.reserve(bytes.len() / 4);
    out.extend(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
    );
    Ok(())
}

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 with padding — the protocol-v3 fallback encoding
/// for bulk stream payloads carried inside JSON frames.
pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let triple = (b0 << 16) | (b1 << 8) | b2;
        out.push(B64_ALPHABET[(triple >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(triple >> 12) as usize & 63] as char);
        if chunk.len() > 1 {
            out.push(B64_ALPHABET[(triple >> 6) as usize & 63] as char);
        } else {
            out.push('=');
        }
        if chunk.len() > 2 {
            out.push(B64_ALPHABET[triple as usize & 63] as char);
        } else {
            out.push('=');
        }
    }
    out
}

fn b64_value(c: u8) -> Result<u32, ReadError> {
    match c {
        b'A'..=b'Z' => Ok((c - b'A') as u32),
        b'a'..=b'z' => Ok((c - b'a' + 26) as u32),
        b'0'..=b'9' => Ok((c - b'0' + 52) as u32),
        b'+' => Ok(62),
        b'/' => Ok(63),
        _ => Err(ReadError(format!("invalid base64 byte 0x{c:02x}"))),
    }
}

/// Decode standard padded base64 (inverse of [`b64_encode`]).
pub fn b64_decode(s: &str) -> Result<Vec<u8>, ReadError> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(ReadError(format!(
            "base64 length {} not a multiple of 4",
            bytes.len()
        )));
    }
    let n_quads = bytes.len() / 4;
    let mut out = Vec::with_capacity(n_quads * 3);
    for (qi, quad) in bytes.chunks_exact(4).enumerate() {
        let pad = quad.iter().filter(|&&c| c == b'=').count();
        let at_end = quad[4 - pad.min(4)..].iter().all(|&c| c == b'=');
        let last = qi + 1 == n_quads;
        if pad > 2 || !at_end || (pad > 0 && !last) {
            return Err(ReadError("misplaced base64 padding".into()));
        }
        let mut triple = 0u32;
        for (i, &c) in quad.iter().enumerate() {
            let v = if c == b'=' { 0 } else { b64_value(c)? };
            triple |= v << (18 - 6 * i as u32);
        }
        out.push((triple >> 16) as u8);
        if pad < 2 {
            out.push((triple >> 8) as u8);
        }
        if pad < 1 {
            out.push(triple as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ints_and_strings() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEADBEEF);
        put_u64(&mut buf, u64::MAX - 1);
        put_str(&mut buf, "vfpga-0");
        let mut r = Reader::new(&buf);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.str().unwrap(), "vfpga-0");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncated_reads_error() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 10);
        let mut r = Reader::new(&buf);
        assert!(r.str().is_err()); // claims 10 bytes, has 0
        let mut r2 = Reader::new(&buf[..2]);
        assert!(r2.u32().is_err());
    }

    #[test]
    fn f32_byte_views() {
        let data = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let bytes = f32_as_bytes(&data);
        assert_eq!(bytes.len(), 16);
        assert_eq!(bytes_to_f32(bytes).unwrap(), data);
    }

    #[test]
    fn bytes_to_f32_rejects_ragged() {
        assert!(bytes_to_f32(&[0, 0, 0]).is_err());
    }

    #[test]
    fn bytes_to_f32_into_reuses_buffer() {
        let data = vec![3.5f32, -0.25];
        let mut out = Vec::new();
        bytes_to_f32_into(f32_as_bytes(&data), &mut out).unwrap();
        assert_eq!(out, data);
        // Second decode reuses the same capacity.
        let cap = out.capacity();
        bytes_to_f32_into(f32_as_bytes(&data), &mut out).unwrap();
        assert_eq!(out, data);
        assert_eq!(out.capacity(), cap);
        assert!(bytes_to_f32_into(&[0, 0, 0], &mut out).is_err());
    }

    #[test]
    fn base64_known_vectors() {
        assert_eq!(b64_encode(b""), "");
        assert_eq!(b64_encode(b"f"), "Zg==");
        assert_eq!(b64_encode(b"fo"), "Zm8=");
        assert_eq!(b64_encode(b"foo"), "Zm9v");
        assert_eq!(b64_encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(b64_decode("Zm9vYmE=").unwrap(), b"fooba");
    }

    #[test]
    fn base64_roundtrip_binary() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1023).collect();
        assert_eq!(b64_decode(&b64_encode(&data)).unwrap(), data);
    }

    #[test]
    fn base64_rejects_malformed() {
        assert!(b64_decode("Zg=").is_err()); // ragged length
        assert!(b64_decode("Z!==").is_err()); // bad alphabet
        assert!(b64_decode("=Zg=").is_err()); // misplaced pad
        assert!(b64_decode("Zg==Zg==").is_err()); // pad mid-stream
    }
}
