//! Filesystem helpers shared by the persistence layers.
//!
//! The control plane stores several small JSON files (device database,
//! scheduler snapshot, bench baselines). A plain `fs::write` can leave a
//! torn file behind if the process dies mid-write — and a torn snapshot
//! is strictly worse than a stale one, because recovery then has nothing
//! to fold the write-ahead log into. `write_atomic` gives the classic
//! durable-replace sequence: write a sibling temp file, flush it to
//! stable storage, rename it over the target, then fsync the directory
//! so the rename itself survives a crash.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::Path;

/// Atomically replace `path` with `contents`.
///
/// The temp file lives next to the target (`<name>.tmp.<pid>`) so the
/// rename stays within one filesystem. On any error the temp file is
/// removed on a best-effort basis and the original file is untouched.
pub fn write_atomic(path: &Path, contents: &str) -> io::Result<()> {
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let file_name = path
        .file_name()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "path has no file name"))?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(format!(".tmp.{}", std::process::id()));
    let tmp = path.with_file_name(tmp_name);

    let res = (|| -> io::Result<()> {
        let mut f = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        if let Some(d) = dir {
            // Persist the rename. Directory fsync can fail on exotic
            // filesystems; the data itself is already safe, so degrade
            // rather than surface an error.
            if let Ok(df) = File::open(d) {
                let _ = df.sync_all();
            }
        }
        Ok(())
    })();

    if res.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("rc3e-fsx-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_replaces() {
        let d = tmpdir("replace");
        let p = d.join("state.json");
        write_atomic(&p, "one").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "one");
        write_atomic(&p, "two").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "two");
        // No temp litter left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&d)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files remain: {:?}", leftovers);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn rejects_bare_root() {
        let err = write_atomic(Path::new("/"), "x").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }
}
