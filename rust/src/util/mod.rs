//! In-tree substrates.
//!
//! The build environment is fully offline and only the `xla` crate's
//! transitive dependency set is vendored, so every generic facility the
//! system needs beyond that — JSON, a virtual clock, a PRNG, CLI
//! parsing, logging, wire encoding — is implemented here rather than
//! pulled from crates.io. Each submodule is small, documented and
//! fully unit-tested.

pub mod bytes;
pub mod cli;
pub mod clock;
pub mod fsx;
pub mod hash;
pub mod ids;
pub mod json;
pub mod logging;
pub mod memprobe;
pub mod rng;
pub mod table;
pub mod trace;
