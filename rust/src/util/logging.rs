//! Leveled logger backing the `log` crate facade.
//!
//! Initialized once per process (`init` is idempotent). Level comes
//! from `RC3E_LOG` (error|warn|info|debug|trace, default `warn` so
//! tests stay quiet). Output goes to stderr with a monotonic
//! wall-clock offset, level, target and message — enough to debug
//! middleware interleavings without pulling in env_logger.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:10.4} {lvl} {}] {}",
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Parse an `RC3E_LOG`-style level word.
pub fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "info" => LevelFilter::Info,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Warn,
    }
}

/// Install the logger (idempotent; later calls only adjust the level).
pub fn init() {
    // Quiet the XLA CPU client's INFO chatter unless the user asked
    // for it (must be set before the first PjRtClient is created).
    if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    }
    let level = std::env::var("RC3E_LOG")
        .map(|v| parse_level(&v))
        .unwrap_or(LevelFilter::Warn);
    init_with_level(level);
}

/// Install with an explicit level (idempotent).
pub fn init_with_level(level: LevelFilter) {
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
    });
    // set_logger fails if already set (e.g. by a previous test) —
    // that's fine, we only need the level updated.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), LevelFilter::Error);
        assert_eq!(parse_level("TRACE"), LevelFilter::Trace);
        assert_eq!(parse_level("bogus"), LevelFilter::Warn);
        assert_eq!(parse_level("off"), LevelFilter::Off);
    }

    #[test]
    fn init_is_idempotent() {
        init_with_level(LevelFilter::Info);
        init_with_level(LevelFilter::Debug);
        assert_eq!(log::max_level(), LevelFilter::Debug);
        log::info!("logger smoke test");
        init_with_level(LevelFilter::Warn);
    }
}
