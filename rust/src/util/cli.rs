//! Tiny command-line argument parser (clap substitute).
//!
//! Supports the subcommand + flags surface the `rc3e` binary and the
//! examples need: `--flag`, `--key value`, `--key=value`, positional
//! arguments, typed accessors with defaults, and auto-generated usage
//! text. Unknown flags are errors so typos fail loudly.

use std::collections::BTreeMap;

/// Declarative specification of one flag.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// Parsed arguments: flags + positionals.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
}

/// Parse error with the offending token.
#[derive(Debug, thiserror::Error)]
#[error("argument error: {0}")]
pub struct ArgError(pub String);

impl Args {
    /// Parse `argv` (without the program name) against `specs`.
    pub fn parse(
        argv: &[String],
        specs: &[FlagSpec],
    ) -> Result<Args, ArgError> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| ArgError(format!("unknown flag --{name}")))?;
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| {
                                ArgError(format!("--{name} needs a value"))
                            })?,
                    };
                    out.flags.insert(name.to_string(), value);
                } else {
                    if inline.is_some() {
                        return Err(ArgError(format!(
                            "--{name} takes no value"
                        )));
                    }
                    out.bools.push(name.to_string());
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    /// String flag value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// u64 flag with default; error if present but unparsable.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| ArgError(format!("--{name}: bad number '{s}'"))),
        }
    }

    /// f64 flag with default.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| ArgError(format!("--{name}: bad number '{s}'"))),
        }
    }

    /// Boolean flag presence.
    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, summary: &str, specs: &[FlagSpec]) -> String {
    let mut out = format!("{cmd} — {summary}\n\nFlags:\n");
    for s in specs {
        let arg = if s.takes_value {
            format!("--{} <v>", s.name)
        } else {
            format!("--{}", s.name)
        };
        out.push_str(&format!("  {arg:<24} {}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec {
                name: "node",
                takes_value: true,
                help: "node id",
            },
            FlagSpec {
                name: "verbose",
                takes_value: false,
                help: "chatty",
            },
            FlagSpec {
                name: "cores",
                takes_value: true,
                help: "core count",
            },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_separate_and_inline_values() {
        let a =
            Args::parse(&sv(&["--node", "n0", "--cores=4"]), &specs()).unwrap();
        assert_eq!(a.get("node"), Some("n0"));
        assert_eq!(a.get_u64("cores", 1).unwrap(), 4);
    }

    #[test]
    fn bool_flags_and_positionals() {
        let a = Args::parse(
            &sv(&["alloc", "--verbose", "vc707"]),
            &specs(),
        )
        .unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.positional(), &["alloc", "vc707"]);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(Args::parse(&sv(&["--bogus"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--node"]), &specs()).is_err());
    }

    #[test]
    fn value_on_bool_flag_is_error() {
        assert!(Args::parse(&sv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&sv(&["--cores", "four"]), &specs()).unwrap();
        assert!(a.get_u64("cores", 1).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get_u64("cores", 2).unwrap(), 2);
        assert_eq!(a.get_or("node", "mgmt"), "mgmt");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn usage_lists_flags() {
        let u = usage("rc3e alloc", "allocate a vFPGA", &specs());
        assert!(u.contains("--node <v>"));
        assert!(u.contains("--verbose"));
    }
}
