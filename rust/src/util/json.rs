//! Minimal but complete JSON implementation (RFC 8259 subset).
//!
//! Used for: the device database persistence, artifact `.meta.json`
//! sidecars, the middleware RPC wire format, batch job specs, and the
//! cluster configuration files. `serde` is unavailable offline, so
//! this module provides a dynamic [`Json`] value plus a hand-rolled
//! recursive-descent parser and a serializer with stable (sorted map)
//! output so database files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A dynamically-typed JSON value.
///
/// Maps use [`BTreeMap`] so serialization order is deterministic —
/// the device database file is diffed in tests and must be stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`Json::parse`], with byte offset for context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    // ---------------------------------------------------------- access

    /// Borrow as object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Borrow as array, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow as string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to u64, if representable.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: &Json = &Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(NULL)
    }

    /// Convenience: string field or error (for required RPC fields).
    pub fn str_field(&self, key: &str) -> Result<&str, String> {
        self.get(key)
            .as_str()
            .ok_or_else(|| format!("missing/invalid string field '{key}'"))
    }

    /// Convenience: u64 field or error.
    pub fn u64_field(&self, key: &str) -> Result<u64, String> {
        self.get(key)
            .as_u64()
            .ok_or_else(|| format!("missing/invalid u64 field '{key}'"))
    }

    /// True if `Json::Null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------- builders

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Insert into an object (no-op on non-objects).
    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        }
    }

    // ---------------------------------------------------------- parse

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------ serialize

    /// Compact serialization (used on the RPC wire).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent (database, configs).
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no inf/nan; fall back to null like most encoders.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs: re-combine.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(
                                || self.err("invalid \\u escape"),
                            )?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (bytes are valid UTF-8
                    // because the input is &str).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| {
                        self.err("invalid utf8")
                    })?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("ascii");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d"), &Json::Bool(true));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\"Aé");
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"t":true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("b", Json::from(1u64)),
            ("a", Json::from(vec![1u64, 2u64])),
        ]);
        let pretty = v.to_pretty();
        let back = Json::parse(&pretty).unwrap();
        assert_eq!(back, v);
        // Deterministic key order: "a" before "b" (BTreeMap).
        assert!(pretty.find("\"a\"").unwrap() < pretty.find("\"b\"").unwrap());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("smile 😀 über".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integer_formatting_has_no_fraction() {
        assert_eq!(Json::Num(798.0).to_string(), "798");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn field_helpers() {
        let v = Json::parse(r#"{"s":"x","n":7}"#).unwrap();
        assert_eq!(v.str_field("s").unwrap(), "x");
        assert_eq!(v.u64_field("n").unwrap(), 7);
        assert!(v.str_field("n").is_err());
        assert!(v.u64_field("missing").is_err());
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut v = Json::Num(1.0);
        for _ in 0..50 {
            v = Json::Arr(vec![v]);
        }
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}
