//! ASCII table renderer for bench reports.
//!
//! Every bench prints a paper-vs-measured table; this keeps the
//! formatting consistent (and testable) across all of them.

/// Column-aligned ASCII table.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Add a row; must match the header arity.
    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for &str cells.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Table {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let sep: String = width
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                line.push_str(&format!("| {}{} ", c, " ".repeat(pad)));
            }
            line.push('|');
            line
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&format!("{sep}\n"));
        out.push_str(&format!("{}\n", fmt_row(&self.header)));
        out.push_str(&format!("{sep}\n"));
        for row in &self.rows {
            out.push_str(&format!("{}\n", fmt_row(row)));
        }
        out.push_str(&format!("{sep}\n"));
        out
    }
}

/// Format a throughput in MB/s with sensible precision.
pub fn mbps(v: f64) -> String {
    format!("{v:.1} MB/s")
}

/// Format a ratio like `0.98x`.
pub fn ratio(measured: f64, paper: f64) -> String {
    if paper == 0.0 {
        "n/a".into()
    } else {
        format!("{:.2}x", measured / paper)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["col", "value"]);
        t.row_str(&["a", "1"]).row_str(&["longer", "22"]);
        let s = t.render();
        assert!(s.contains("| col    | value |"));
        assert!(s.contains("| longer | 22    |"));
        // All separator lines equal length.
        let seps: Vec<&str> =
            s.lines().filter(|l| l.starts_with('+')).collect();
        assert_eq!(seps.len(), 3);
        assert!(seps.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        Table::new("t", &["a", "b"]).row_str(&["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(mbps(797.96), "798.0 MB/s");
        assert_eq!(ratio(509.0, 509.0), "1.00x");
        assert_eq!(ratio(1.0, 0.0), "n/a");
    }

    #[test]
    fn unicode_width_counts_chars() {
        let mut t = Table::new("u", &["név"]);
        t.row_str(&["érték"]);
        let s = t.render();
        assert!(s.contains("| név   |"));
    }
}
