//! In-tree content hashing: SHA-256 and CRC-32.
//!
//! The build environment is fully offline (see [`super`]), so the two
//! digest primitives the system depends on live here rather than
//! behind external crates: SHA-256 names bitstream content (the
//! identity the database, the region state and the bitstream cache
//! key on), and CRC-32 (IEEE 802.3, reflected — the Xilinx
//! config-logic polynomial) guards payload integrity in both the
//! journal record framing and bitstream admission checks.

/// Streaming SHA-256 (FIPS 180-4). Feed bytes with [`Sha256::update`],
/// then take the 32-byte digest with [`Sha256::finalize`].
#[derive(Debug, Clone)]
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Sha256 {
        Sha256::new()
    }
}

/// Round constants (first 32 bits of the fractional parts of the cube
/// roots of the first 64 primes).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b,
    0x59f111f1, 0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01,
    0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7,
    0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc,
    0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152,
    0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819,
    0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116, 0x1e376c08,
    0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f,
    0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            // First 32 bits of the fractional parts of the square
            // roots of the first 8 primes.
            state: [
                0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
                0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
            ],
            buf: [0u8; 64],
            buf_len: 0,
            total_len: 0,
        }
    }

    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take]
                .copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            compress(&mut self.state, &block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // Pad: 0x80, zeros to 56 mod 64, then the bit length BE.
        self.update([0x80u8]);
        while self.buf_len != 56 {
            self.update([0u8]);
        }
        self.update(bit_len.to_be_bytes());
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (chunk, word) in
            out.chunks_exact_mut(4).zip(self.state.iter())
        {
            chunk.copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

/// One FIPS 180-4 compression round over a 64-byte block.
fn compress(state: &mut [u32; 8], block: &[u8; 64]) {
    let mut w = [0u32; 64];
    for (word, chunk) in w.iter_mut().zip(block.chunks_exact(4)) {
        *word =
            u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7)
            ^ w[i - 15].rotate_right(18)
            ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17)
            ^ w[i - 2].rotate_right(19)
            ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] =
        *state;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11)
            ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13)
            ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (s, v) in state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
        *s = s.wrapping_add(v);
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// One-shot SHA-256 as a lowercase hex string.
pub fn sha256_hex(data: &[u8]) -> String {
    hex(&sha256(data))
}

/// Lowercase hex encoding.
pub fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// Standard CRC-32 (IEEE 802.3, reflected), table built at compile
/// time — the build is offline, so no external crc crate.
const CRC32_TABLE: [u32; 256] = build_crc32_table();

const fn build_crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Fold `bytes` into a running (pre-inverted) CRC state. Start from
/// `0xFFFF_FFFF` and invert the result — or use [`crc32`] for the
/// one-shot form.
pub fn crc32_update(mut crc: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        crc = CRC32_TABLE[((crc ^ b as u32) & 0xFF) as usize]
            ^ (crc >> 8);
    }
    crc
}

/// One-shot CRC-32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    !crc32_update(0xFFFF_FFFF, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_matches_fips_vectors() {
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223\
             b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb924\
             27ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn sha256_two_block_vector() {
        // 56 bytes forces the length into a second padding block.
        let msg = b"abcdbcdecdefdefgefghfghighijhijk\
                    ijkljklmklmnlmnomnopnopq";
        assert_eq!(
            sha256_hex(msg),
            "248d6a61d20638b8e5c026930c3e6039\
             a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn streaming_matches_one_shot_across_boundaries() {
        let data: Vec<u8> = (0..257u16).map(|i| i as u8).collect();
        for split in [0usize, 1, 63, 64, 65, 128, 200, 257] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), sha256(&data), "split={split}");
        }
    }

    #[test]
    fn crc32_matches_known_vector() {
        // CRC-32("123456789") = 0xCBF43926 (IEEE check value).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        // Incremental folding matches the one-shot form.
        let mut crc = crc32_update(0xFFFF_FFFF, b"12345");
        crc = crc32_update(crc, b"6789");
        assert_eq!(!crc, 0xCBF4_3926);
    }
}
