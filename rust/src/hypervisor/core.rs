//! The RC3E hypervisor proper.
//!
//! Owns every managed device (simulated board + RC2F controller +
//! PCIe link + device-file namespace), the device database, the
//! bitfile sanity checker and the placement policy. All timed
//! operations charge the shared virtual clock; the middleware layer
//! on top adds the RPC hop, which together reproduce Table I's
//! local-vs-over-RC3E deltas.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::db::{AllocKind, DeviceDb, DeviceEntry};
use super::guard::{PinGuard, QuiesceGuard, RegionGuards};
use super::overhead;
use super::placement::{Candidate, PlacementPolicy};
use crate::bitstream::{Bitstream, SanityChecker, SanityPolicy};
use crate::config::{ClusterConfig, ServiceModel};
use crate::fpga::board::BoardSpec;
use crate::fpga::device::{DeviceStatus, FpgaDevice};
use crate::fpga::lifecycle::LifecycleState;
use crate::hls::flow::region_window;
use crate::pcie::devfile::DeviceFileRegistry;
use crate::pcie::{DeviceLink, LinkParams};
use crate::rc2f::components::Rc2fDesign;
use crate::rc2f::controller::Controller;
use crate::rc2f::host_api::HostApi;
use crate::util::clock::{VirtualClock, VirtualTime};
use crate::util::ids::{AllocationId, FpgaId, NodeId, UserId, VfpgaId, VmId};
use crate::util::trace;

/// Errors from hypervisor operations.
#[derive(Debug, thiserror::Error)]
pub enum HypervisorError {
    #[error("no capacity for the request")]
    NoCapacity,
    #[error("database: {0}")]
    Db(String),
    #[error("device: {0}")]
    Device(String),
    #[error("sanity: {0}")]
    Sanity(#[from] crate::bitstream::SanityError),
    #[error("allocation {0} not found or not yours")]
    BadAllocation(AllocationId),
    #[error("allocation {0} is not of the required kind")]
    WrongKind(AllocationId),
    #[error("unknown device {0}")]
    UnknownDevice(FpgaId),
    #[error("unknown service '{0}'")]
    UnknownService(String),
    #[error("scheduler: {0}")]
    Sched(String),
}

/// Everything the hypervisor holds for one physical board.
pub struct ManagedDevice {
    pub node: NodeId,
    pub fpga: Mutex<FpgaDevice>,
    pub controller: Arc<Mutex<Controller>>,
    pub link: Arc<DeviceLink>,
    pub models: Vec<ServiceModel>,
    /// Slot index of each region id (for frame-window lookup).
    pub slot_of: BTreeMap<VfpgaId, usize>,
}

/// The hypervisor.
pub struct Hypervisor {
    pub clock: Arc<VirtualClock>,
    pub db: Mutex<DeviceDb>,
    devices: BTreeMap<FpgaId, ManagedDevice>,
    registries: BTreeMap<NodeId, Arc<DeviceFileRegistry>>,
    checker: SanityChecker,
    policy: PlacementPolicy,
    /// Last bitstream programmed into each region (migration input).
    programmed: Mutex<BTreeMap<VfpgaId, Bitstream>>,
    /// Provider bitfile store for BAaaS services.
    services: Mutex<BTreeMap<String, Bitstream>>,
    /// Pin/quiesce guards over every region (see [`super::guard`]).
    guards: Arc<RegionGuards>,
    pub metrics: Arc<crate::metrics::Registry>,
}

impl Hypervisor {
    /// Boot the cloud from a configuration: create devices, load the
    /// RC2F basic design on every RAaaS/BAaaS device (charging the
    /// full JTAG configuration time per device) and register
    /// everything in the database.
    pub fn boot(
        config: &ClusterConfig,
        clock: Arc<VirtualClock>,
        policy: PlacementPolicy,
    ) -> Result<Hypervisor, HypervisorError> {
        let sanity = if config.require_signatures {
            SanityPolicy::production()
        } else {
            SanityPolicy::research()
        };
        let metrics = Arc::new(crate::metrics::Registry::new());
        let mut hv = Hypervisor {
            clock: Arc::clone(&clock),
            db: Mutex::new(DeviceDb::new()),
            devices: BTreeMap::new(),
            registries: BTreeMap::new(),
            checker: SanityChecker::new(sanity),
            policy,
            programmed: Mutex::new(BTreeMap::new()),
            services: Mutex::new(BTreeMap::new()),
            guards: RegionGuards::new(),
            metrics,
        };
        let mut fpga_seq = 0u64;
        for (ni, node) in config.nodes.iter().enumerate() {
            let node_id = NodeId(ni as u64);
            let registry = Arc::new(DeviceFileRegistry::new());
            hv.registries.insert(node_id, registry.clone());
            for fc in &node.fpgas {
                let fpga_id = FpgaId(fpga_seq);
                fpga_seq += 1;
                let board = BoardSpec::of(fc.board);
                let mut dev =
                    FpgaDevice::new(fpga_id, board, Arc::clone(&clock));
                dev.set_metrics(Arc::clone(&hv.metrics));
                let serves_vfpgas = fc.models.iter().any(|m| {
                    matches!(m, ServiceModel::RAaaS | ServiceModel::BAaaS)
                });
                let mut regions = Vec::new();
                if serves_vfpgas {
                    let design = Rc2fDesign::new(fc.vfpgas);
                    let bs = crate::bitstream::BitstreamBuilder::full(
                        dev.board.part,
                        &design.name(),
                    )
                    .resources(design.total_resources())
                    .vfpga_regions(fc.vfpgas)
                    .payload_len(dev.board.full_bitstream_bytes as usize / 1024)
                    .build();
                    dev.configure_full(&bs)
                        .map_err(|e| HypervisorError::Device(e.to_string()))?;
                    regions =
                        dev.regions().iter().map(|r| r.id).collect::<Vec<_>>();
                }
                let slot_of = regions
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| (v, i))
                    .collect();
                let controller = Arc::new(Mutex::new(Controller::new(
                    Arc::clone(&clock),
                    &regions,
                )));
                registry.create_gcs(fpga_id);
                let link =
                    DeviceLink::new(Arc::clone(&clock), LinkParams::gen2_x4());
                hv.db.lock().unwrap().add_device(DeviceEntry {
                    id: fpga_id,
                    node: node_id,
                    board: fc.board,
                    regions,
                    models: fc.models.clone(),
                    exclusive_alloc: None,
                });
                hv.devices.insert(
                    fpga_id,
                    ManagedDevice {
                        node: node_id,
                        fpga: Mutex::new(dev),
                        controller,
                        link,
                        models: fc.models.clone(),
                        slot_of,
                    },
                );
            }
        }
        Ok(hv)
    }

    /// Paper testbed with consolidate-first placement.
    pub fn boot_paper_testbed(
        clock: Arc<VirtualClock>,
    ) -> Result<Hypervisor, HypervisorError> {
        Hypervisor::boot(
            &ClusterConfig::paper_testbed(),
            clock,
            PlacementPolicy::ConsolidateFirst,
        )
    }

    pub fn device(&self, id: FpgaId) -> Result<&ManagedDevice, HypervisorError> {
        self.devices.get(&id).ok_or(HypervisorError::UnknownDevice(id))
    }

    pub fn device_ids(&self) -> Vec<FpgaId> {
        self.devices.keys().copied().collect()
    }

    /// Install a sink invoked on every validated region lifecycle
    /// transition, across all devices (the middleware server wires
    /// this to the protocol-3 event bus).
    pub fn set_region_transition_sink(
        &self,
        sink: crate::fpga::TransitionSink,
    ) {
        for dev in self.devices.values() {
            dev.fpga
                .lock()
                .unwrap()
                .set_transition_sink(Arc::clone(&sink));
        }
    }

    pub fn registry(&self, node: NodeId) -> Option<&Arc<DeviceFileRegistry>> {
        self.registries.get(&node)
    }

    pub fn add_user(&self, name: &str) -> UserId {
        self.db.lock().unwrap().add_user(name)
    }

    // --------------------------------------------------- allocation

    /// Allocate one vFPGA under RAaaS/BAaaS using the placement
    /// policy. Creates the user's device files.
    pub fn alloc_vfpga(
        &self,
        user: UserId,
        model: ServiceModel,
    ) -> Result<(AllocationId, VfpgaId, FpgaId, NodeId), HypervisorError>
    {
        assert!(
            !matches!(model, ServiceModel::RSaaS),
            "RSaaS uses alloc_physical"
        );
        let mut db = self.db.lock().unwrap();
        let candidates: Vec<Candidate> = self
            .devices
            .iter()
            .filter(|(_, d)| d.models.contains(&model))
            .map(|(id, _)| Candidate {
                fpga: *id,
                used: db.used_regions(*id),
                free: db.free_regions(*id),
            })
            .collect();
        let (fpga, vfpga) = self
            .policy
            .choose(&candidates)
            .ok_or(HypervisorError::NoCapacity)?;
        let alloc = db
            .allocate_vfpga(user, vfpga, model, self.clock.now().0)
            .map_err(HypervisorError::Db)?;
        drop(db);
        let dev = self.device(fpga)?;
        dev.controller
            .lock()
            .unwrap()
            .allocate(vfpga, user)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.registries[&dev.node]
            .create_vfpga_files(vfpga, user)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;
        // The region is claimed: enter the lifecycle machine.
        dev.fpga
            .lock()
            .unwrap()
            .transition_region(vfpga, LifecycleState::Reserved)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.metrics.counter("hv.alloc.vfpga").inc();
        self.refresh_region_gauges();
        Ok((alloc, vfpga, fpga, dev.node))
    }

    /// Allocate one *specific* free vFPGA region under RAaaS/BAaaS —
    /// the second phase of the scheduler's gang admission, which has
    /// already picked its candidate regions and needs them claimed
    /// exactly (no placement-policy freedom). Fails with
    /// [`HypervisorError::NoCapacity`] when the region was taken by a
    /// racing allocation (the caller rolls the gang back).
    pub fn alloc_vfpga_on(
        &self,
        user: UserId,
        model: ServiceModel,
        vfpga: VfpgaId,
    ) -> Result<(AllocationId, VfpgaId, FpgaId, NodeId), HypervisorError>
    {
        assert!(
            !matches!(model, ServiceModel::RSaaS),
            "RSaaS uses alloc_physical"
        );
        let mut db = self.db.lock().unwrap();
        let fpga = db
            .device_of_vfpga(vfpga)
            .map(|d| d.id)
            .ok_or_else(|| {
                HypervisorError::Db(format!("{vfpga} not in database"))
            })?;
        let serves = db
            .device(fpga)
            .map(|d| d.models.contains(&model))
            .unwrap_or(false);
        if !serves || !db.free_regions(fpga).contains(&vfpga) {
            return Err(HypervisorError::NoCapacity);
        }
        let alloc = db
            .allocate_vfpga(user, vfpga, model, self.clock.now().0)
            .map_err(|_| HypervisorError::NoCapacity)?;
        drop(db);
        let dev = self.device(fpga)?;
        dev.controller
            .lock()
            .unwrap()
            .allocate(vfpga, user)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.registries[&dev.node]
            .create_vfpga_files(vfpga, user)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;
        // The region is claimed: enter the lifecycle machine.
        dev.fpga
            .lock()
            .unwrap()
            .transition_region(vfpga, LifecycleState::Reserved)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.metrics.counter("hv.alloc.vfpga").inc();
        self.refresh_region_gauges();
        Ok((alloc, vfpga, fpga, dev.node))
    }

    /// Allocate a whole physical FPGA (RSaaS), optionally wrapped in
    /// a VM with the device passed through.
    pub fn alloc_physical(
        &self,
        user: UserId,
        vm: Option<VmId>,
    ) -> Result<(AllocationId, FpgaId, NodeId), HypervisorError> {
        let mut db = self.db.lock().unwrap();
        // Deterministic scan: first RSaaS-capable device with no
        // leases at all.
        let target = self
            .devices
            .iter()
            .find(|(id, d)| {
                d.models.contains(&ServiceModel::RSaaS)
                    && db.used_regions(**id) == 0
                    && db
                        .device(**id)
                        .map(|e| e.exclusive_alloc.is_none())
                        .unwrap_or(false)
            })
            .map(|(id, d)| (*id, d.node));
        let (fpga, node) = target.ok_or(HypervisorError::NoCapacity)?;
        let alloc = db
            .allocate_physical(user, fpga, vm, self.clock.now().0)
            .map_err(HypervisorError::Db)?;
        self.metrics.counter("hv.alloc.physical").inc();
        Ok((alloc, fpga, node))
    }

    /// Re-adopt a vFPGA allocation recovered from the scheduler's
    /// write-ahead log after a restart: re-insert it into the device
    /// database under its *original* [`AllocationId`], re-register the
    /// clock domain with the RC2F controller, re-create the tenant's
    /// device files and re-enter the lifecycle machine at `Reserved`.
    /// The bitstream itself does not survive the crash — the tenant
    /// reprograms, exactly as after a relocation.
    pub fn adopt_vfpga(
        &self,
        alloc: AllocationId,
        user: UserId,
        model: ServiceModel,
        vfpga: VfpgaId,
    ) -> Result<(FpgaId, NodeId), HypervisorError> {
        assert!(
            !matches!(model, ServiceModel::RSaaS),
            "RSaaS uses adopt_physical"
        );
        let mut db = self.db.lock().unwrap();
        let fpga = db
            .device_of_vfpga(vfpga)
            .map(|d| d.id)
            .ok_or_else(|| {
                HypervisorError::Db(format!("{vfpga} not in database"))
            })?;
        db.adopt_allocation(
            alloc,
            user,
            AllocKind::Vfpga(vfpga),
            model,
            self.clock.now().0,
        )
        .map_err(HypervisorError::Db)?;
        drop(db);
        let dev = self.device(fpga)?;
        dev.controller
            .lock()
            .unwrap()
            .allocate(vfpga, user)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.registries[&dev.node]
            .create_vfpga_files(vfpga, user)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;
        dev.fpga
            .lock()
            .unwrap()
            .transition_region(vfpga, LifecycleState::Reserved)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.metrics.counter("hv.adopt").inc();
        self.refresh_region_gauges();
        Ok((fpga, dev.node))
    }

    /// Re-adopt an exclusive physical allocation (RSaaS) recovered
    /// from the scheduler's write-ahead log. Database-only, like
    /// [`Hypervisor::alloc_physical`]. VM passthrough identity is not
    /// journaled, so a lease born as `AllocKind::Vm` is re-adopted as
    /// plain `Physical` — the exclusivity and accounting are
    /// identical; the tenant re-attaches the VM out of band.
    pub fn adopt_physical(
        &self,
        alloc: AllocationId,
        user: UserId,
        fpga: FpgaId,
    ) -> Result<NodeId, HypervisorError> {
        let node = self.device(fpga)?.node;
        self.db
            .lock()
            .unwrap()
            .adopt_allocation(
                alloc,
                user,
                AllocKind::Physical(fpga),
                ServiceModel::RSaaS,
                self.clock.now().0,
            )
            .map_err(HypervisorError::Db)?;
        self.metrics.counter("hv.adopt").inc();
        Ok(node)
    }

    /// Release any allocation: blanks regions, gates clocks, removes
    /// device files, updates the database.
    ///
    /// vFPGA releases first win a quiesce on the lease's region, so
    /// an in-flight program/stream pin drains before teardown — the
    /// same structural no-race rule relocation follows. The pinned
    /// operation completes; its *next* lease resolution then fails
    /// cleanly against the released allocation.
    pub fn release(&self, id: AllocationId) -> Result<(), HypervisorError> {
        let _quiesce = self.quiesce_allocation(id);
        let alloc = self
            .db
            .lock()
            .unwrap()
            .release(id)
            .map_err(HypervisorError::Db)?;
        match alloc.kind {
            AllocKind::Vfpga(v) => {
                let entry = {
                    let db = self.db.lock().unwrap();
                    db.device_of_vfpga(v).map(|d| (d.id, d.node))
                };
                if let Some((fpga, node)) = entry {
                    let dev = self.device(fpga)?;
                    let mut hw = dev.fpga.lock().unwrap();
                    if hw.region(v).map(|r| r.is_configured()).unwrap_or(false)
                    {
                        hw.clear_region(v).map_err(|e| {
                            HypervisorError::Device(e.to_string())
                        })?;
                    } else if hw
                        .region(v)
                        .map(|r| r.lifecycle != LifecycleState::Free)
                        .unwrap_or(false)
                    {
                        // Never programmed: no blanking PR to charge,
                        // but the claim still returns to Free.
                        hw.transition_region(v, LifecycleState::Free)
                            .map_err(|e| {
                                HypervisorError::Device(e.to_string())
                            })?;
                    }
                    drop(hw);
                    dev.controller
                        .lock()
                        .unwrap()
                        .release(v)
                        .map_err(|e| HypervisorError::Device(e.to_string()))?;
                    self.registries[&node].remove_vfpga_files(v);
                    self.programmed.lock().unwrap().remove(&v);
                }
            }
            AllocKind::Physical(_) | AllocKind::Vm(_, _) => {}
        }
        self.metrics.counter("hv.release").inc();
        self.refresh_region_gauges();
        Ok(())
    }

    // ------------------------------------------------- programming

    /// Partially reconfigure an allocated vFPGA with a user bitfile.
    /// Runs the sanity checker first (frame window + capacity +
    /// integrity + signature policy), then PR, then updates the
    /// controller. Charges the RC3E PR orchestration overhead.
    /// Returns the total charged duration.
    ///
    /// The whole orchestration runs under a region pin and marks the
    /// region `Programming` up front, so a quiesce-based relocation
    /// or release can neither start mid-PR nor ever observe the
    /// region half-programmed. On failure the region returns to the
    /// state it came from (`Reserved` or `Active`).
    pub fn program_vfpga(
        &self,
        alloc_id: AllocationId,
        user: UserId,
        bs: &Bitstream,
    ) -> Result<VirtualTime, HypervisorError> {
        let (_pin, vfpga) = self.pin_current(alloc_id, user)?;
        self.program_vfpga_at(vfpga, bs)
    }

    /// The pinless PR orchestration body: the caller must already
    /// exclude concurrent relocation of `vfpga` — either by a pin
    /// ([`Self::program_vfpga`]) or by a quiesce (the migration path
    /// programs its target under the target's own quiesce, where a
    /// pin would self-deadlock).
    pub(crate) fn program_vfpga_at(
        &self,
        vfpga: VfpgaId,
        bs: &Bitstream,
    ) -> Result<VirtualTime, HypervisorError> {
        let sp = trace::span("hv.program");
        sp.attr("vfpga", vfpga);
        sp.attr("core", &bs.meta.core);
        let fpga = self.fpga_of_vfpga(vfpga)?;
        let dev = self.device(fpga)?;
        // Resident-design fast path: the region is Active and still
        // holds exactly this content (same sha over header+payload,
        // hence the same design retargeted to the same slot) — the
        // fabric already is what PR would produce, so skip the
        // reconfiguration entirely.
        let resident = {
            let hw = dev.fpga.lock().unwrap();
            hw.region(vfpga)
                .ok()
                .filter(|r| r.lifecycle == LifecycleState::Active)
                .and_then(|r| r.design.as_ref())
                .map(|d| d.bitstream_sha == bs.sha256)
                .unwrap_or(false)
        };
        if resident {
            self.programmed
                .lock()
                .unwrap()
                .insert(vfpga, bs.clone());
            self.metrics.counter("bitcache.resident_skip").inc();
            sp.attr("resident", true);
            return Ok(VirtualTime::from_millis_f64(0.0));
        }
        let t0 = self.clock.now();
        let from = dev
            .fpga
            .lock()
            .unwrap()
            .transition_region(vfpga, LifecycleState::Programming)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        if let Err(e) = self.program_vfpga_inner(dev, vfpga, bs) {
            // Distinguish "fabric untouched" (sanity/PR rejected
            // before writing — roll back to where the region came
            // from) from "PR landed but post-PR bookkeeping failed"
            // (the region is already Active and holds the design —
            // record it so the device and the programmed map agree).
            let lifecycle = {
                let mut hw = dev.fpga.lock().unwrap();
                let lifecycle = hw
                    .region(vfpga)
                    .map(|r| r.lifecycle)
                    .unwrap_or(from);
                if lifecycle == LifecycleState::Programming {
                    let _ = hw.transition_region(vfpga, from);
                }
                lifecycle
            };
            if lifecycle == LifecycleState::Active {
                self.programmed
                    .lock()
                    .unwrap()
                    .insert(vfpga, bs.clone());
            }
            self.refresh_region_gauges();
            sp.fail(&e);
            return Err(e);
        }
        self.programmed
            .lock()
            .unwrap()
            .insert(vfpga, bs.clone());
        self.metrics.counter("hv.pr").inc();
        self.metrics
            .histogram("hv.pr.ms")
            .record_us((self.clock.since(t0).as_millis_f64() * 1e3) as u64);
        self.refresh_region_gauges();
        Ok(self.clock.since(t0))
    }

    /// The fallible middle of [`Self::program_vfpga`]: sanity check,
    /// orchestration charge, PR (`Programming -> Active` on success),
    /// controller update.
    fn program_vfpga_inner(
        &self,
        dev: &ManagedDevice,
        vfpga: VfpgaId,
        bs: &Bitstream,
    ) -> Result<(), HypervisorError> {
        {
            // Bitfile sanity gate: frame window + capacity +
            // integrity + signature policy.
            let load = trace::span("bitstream.load");
            let hw = dev.fpga.lock().unwrap();
            let slot = dev.slot_of[&vfpga];
            let region = hw
                .region(vfpga)
                .map_err(|e| HypervisorError::Device(e.to_string()))?;
            if let Err(e) = self.checker.check_partial(
                bs,
                hw.board.part,
                region_window(slot, region.shape.quarters()),
                region.capacity,
            ) {
                load.fail(&e);
                return Err(e.into());
            }
        }
        {
            let pr = trace::span("fpga.pr");
            self.clock.advance(VirtualTime::from_millis_f64(
                overhead::PR_ORCH_MS,
            ));
            if let Err(e) = dev
                .fpga
                .lock()
                .unwrap()
                .configure_partial(vfpga, bs)
                .map_err(|e| HypervisorError::Device(e.to_string()))
            {
                pr.fail(&e);
                return Err(e);
            }
        }
        dev.controller
            .lock()
            .unwrap()
            .mark_configured(vfpga, &bs.meta.core)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        Ok(())
    }

    // --------------------------------------------- region guards

    /// The pin/quiesce guard table (lease handles and the scheduler
    /// pin/quiesce through these).
    pub fn guards(&self) -> &Arc<RegionGuards> {
        &self.guards
    }

    /// Pin the region a lease currently occupies. If a relocation
    /// rebinds the lease between resolving and pinning, the stale pin
    /// is dropped and the new region pinned instead — the returned
    /// pair is always consistent.
    pub fn pin_current(
        &self,
        alloc_id: AllocationId,
        user: UserId,
    ) -> Result<(PinGuard, VfpgaId), HypervisorError> {
        loop {
            let vfpga = self.check_vfpga_lease(alloc_id, user)?;
            let pin = self.guards.pin(vfpga);
            if self.check_vfpga_lease(alloc_id, user)? == vfpga {
                return Ok((pin, vfpga));
            }
        }
    }

    /// Retarget + program under one pin: the placement resolved for
    /// retargeting is exactly the placement programmed (the
    /// `program_core` RPC path; lease handles do the same through
    /// `Lease::program_member`).
    pub fn program_retargeted(
        &self,
        alloc_id: AllocationId,
        user: UserId,
        bitfile: &Bitstream,
    ) -> Result<VirtualTime, HypervisorError> {
        let (_pin, vfpga) = self.pin_current(alloc_id, user)?;
        let placed = self.retarget_for(vfpga, bitfile)?;
        self.program_vfpga(alloc_id, user, &placed)
    }

    /// Win a quiesce on a region, blocking while pins drain; records
    /// the wall wait in `sched.preempt.quiesce_wait`.
    pub fn quiesce_region(&self, vfpga: VfpgaId) -> QuiesceGuard {
        let sp = trace::span("hv.quiesce");
        sp.attr("vfpga", vfpga);
        let (guard, waited) = self.guards.quiesce_blocking(vfpga);
        self.metrics
            .histogram("sched.preempt.quiesce_wait")
            .record_us(waited.as_micros() as u64);
        guard
    }

    /// Non-blocking quiesce (preemption's only-quiescable-victims
    /// rule). A win records a zero wait.
    pub fn try_quiesce_region(
        &self,
        vfpga: VfpgaId,
    ) -> Option<QuiesceGuard> {
        let guard = self.guards.try_quiesce(vfpga);
        if guard.is_some() {
            self.metrics
                .histogram("sched.preempt.quiesce_wait")
                .record_us(0);
        }
        guard
    }

    /// Win a quiesce on the region an allocation currently holds,
    /// re-resolving if a relocation moved the lease while we waited.
    /// `None` for non-vFPGA or already-gone allocations.
    fn quiesce_allocation(&self, id: AllocationId) -> Option<QuiesceGuard> {
        loop {
            let vfpga = {
                let db = self.db.lock().unwrap();
                db.allocation(id).and_then(|a| match a.kind {
                    AllocKind::Vfpga(v) => Some(v),
                    _ => None,
                })
            }?;
            let guard = self.quiesce_region(vfpga);
            let still = {
                let db = self.db.lock().unwrap();
                db.allocation(id)
                    .map(|a| a.kind == AllocKind::Vfpga(vfpga))
                    .unwrap_or(false)
            };
            if still {
                return Some(guard);
            }
        }
    }

    /// Recompute the per-state region occupancy gauges
    /// (`region.state.<name>`). Cheap: a few devices, a few regions.
    pub fn refresh_region_gauges(&self) {
        let mut counts = [0i64; 6];
        for dev in self.devices.values() {
            let hw = dev.fpga.lock().unwrap();
            for r in hw.regions() {
                counts[r.lifecycle as usize] += 1;
            }
        }
        for (i, s) in LifecycleState::ALL.iter().enumerate() {
            self.metrics
                .gauge(&format!("region.state.{}", s.name()))
                .set(counts[i]);
        }
    }

    /// Full reconfiguration of an exclusively-held device (RSaaS):
    /// snapshot PCIe link params, configure, restore (hot-plug).
    pub fn program_full(
        &self,
        alloc_id: AllocationId,
        user: UserId,
        bs: &Bitstream,
    ) -> Result<VirtualTime, HypervisorError> {
        let sp = trace::span("hv.full_config");
        sp.attr("alloc", alloc_id);
        let fpga = {
            let db = self.db.lock().unwrap();
            let alloc = db
                .allocation(alloc_id)
                .filter(|a| a.user == user)
                .ok_or(HypervisorError::BadAllocation(alloc_id))?;
            match alloc.kind {
                AllocKind::Physical(f) | AllocKind::Vm(_, f) => f,
                _ => return Err(HypervisorError::WrongKind(alloc_id)),
            }
        };
        let dev = self.device(fpga)?;
        let t0 = self.clock.now();
        let mut hw = dev.fpga.lock().unwrap();
        {
            let load = trace::span("bitstream.load");
            if let Err(e) = self.checker.check_full(bs, hw.board.part) {
                load.fail(&e);
                return Err(e.into());
            }
        }
        // PCIe hot-plug: save params, reconfigure, restore.
        hw.save_link_params(dev.link.params);
        self.clock.advance(VirtualTime::from_millis_f64(
            overhead::FULL_CONFIG_ORCH_MS,
        ));
        hw.configure_full(bs)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        let _restored = hw.restore_link_params();
        self.metrics.counter("hv.full_config").inc();
        Ok(self.clock.since(t0))
    }

    // ------------------------------------------------------ status

    /// RC2F status call as the node sees it ("local without RC3E"):
    /// device-file open + gcs read. Reproduces Table I's ~11 ms.
    pub fn status_local(
        &self,
        fpga: FpgaId,
    ) -> Result<DeviceStatus, HypervisorError> {
        let dev = self.device(fpga)?;
        self.clock.advance(VirtualTime::from_millis_f64(
            overhead::STATUS_DEVFILE_MS,
        ));
        // gcs access through the controller charges Table II latency.
        let _ = dev
            .controller
            .lock()
            .unwrap()
            .gcs_read(crate::rc2f::controller::gcs_reg::STATUS)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        Ok(dev.fpga.lock().unwrap().status())
    }

    // ------------------------------------------------------ energy

    /// Total instantaneous power across devices.
    pub fn total_power_w(&self) -> f64 {
        self.devices
            .values()
            .map(|d| d.fpga.lock().unwrap().status().power_w)
            .sum()
    }

    /// Total integrated energy across devices.
    pub fn total_energy_joules(&self) -> f64 {
        self.devices
            .values()
            .map(|d| d.fpga.lock().unwrap().energy_joules())
            .sum()
    }

    // ---------------------------------------------------- services

    /// Register a provider bitfile for a BAaaS service.
    pub fn register_service(&self, name: &str, bs: Bitstream) {
        self.services.lock().unwrap().insert(name.to_string(), bs);
    }

    pub fn service_bitfile(
        &self,
        name: &str,
    ) -> Result<Bitstream, HypervisorError> {
        self.services
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| HypervisorError::UnknownService(name.to_string()))
    }

    pub fn service_names(&self) -> Vec<String> {
        self.services.lock().unwrap().keys().cloned().collect()
    }

    // ---------------------------------------------------- sessions

    /// Host API endpoint for a device (RAaaS user side).
    pub fn host_api(&self, fpga: FpgaId) -> Result<Arc<HostApi>, HypervisorError> {
        let dev = self.device(fpga)?;
        Ok(Arc::new(HostApi::new(
            Arc::clone(&dev.controller),
            Arc::clone(&self.registries[&dev.node]),
            Arc::clone(&dev.link),
            Arc::clone(&self.clock),
        )))
    }

    /// Verify a lease and return its vFPGA.
    pub fn check_vfpga_lease(
        &self,
        alloc_id: AllocationId,
        user: UserId,
    ) -> Result<VfpgaId, HypervisorError> {
        let db = self.db.lock().unwrap();
        let alloc = db
            .allocation(alloc_id)
            .filter(|a| a.user == user)
            .ok_or(HypervisorError::BadAllocation(alloc_id))?;
        match alloc.kind {
            AllocKind::Vfpga(v) => Ok(v),
            _ => Err(HypervisorError::WrongKind(alloc_id)),
        }
    }

    /// The bitstream last programmed into a region (migration input).
    pub fn programmed_bitstream(&self, v: VfpgaId) -> Option<Bitstream> {
        self.programmed.lock().unwrap().get(&v).cloned()
    }

    /// Drop the programmed-bitstream record of a region (a vacated
    /// migration source, or a rollback that orphaned the design).
    pub(crate) fn forget_programmed(&self, v: VfpgaId) {
        self.programmed.lock().unwrap().remove(&v);
    }

    /// Device currently hosting a vFPGA region (lease resolution).
    fn fpga_of_vfpga(&self, vfpga: VfpgaId) -> Result<FpgaId, HypervisorError> {
        let db = self.db.lock().unwrap();
        db.device_of_vfpga(vfpga)
            .map(|d| d.id)
            .ok_or_else(|| {
                HypervisorError::Db(format!("{vfpga} not in database"))
            })
    }

    /// Stream runner bound to the device currently hosting `vfpga` —
    /// the streaming half of lease resolution (see [`Self::retarget_for`]
    /// for the programming half). Callers re-resolve through the
    /// lease right before streaming so a preemption-migration between
    /// steps streams through the new device's link.
    pub fn stream_runner_for(
        &self,
        vfpga: VfpgaId,
    ) -> Result<crate::rc2f::stream::StreamRunner, HypervisorError> {
        let dev = self.device(self.fpga_of_vfpga(vfpga)?)?;
        Ok(crate::rc2f::stream::StreamRunner::new(
            Arc::clone(&self.clock),
            Arc::clone(&dev.link),
        )
        .with_metrics(Arc::clone(&self.metrics)))
    }

    /// Retarget a relocatable partial bitfile to wherever `vfpga`
    /// actually sits (slot + region size) — the paper's region-hiding
    /// feature. Single device-DB lookup; every programming path
    /// (services, batch, middleware, migration callers) shares this.
    pub fn retarget_for(
        &self,
        vfpga: VfpgaId,
        bitfile: &Bitstream,
    ) -> Result<Bitstream, HypervisorError> {
        let dev = self.device(self.fpga_of_vfpga(vfpga)?)?;
        let slot = dev.slot_of[&vfpga];
        let quarters = {
            let hw = dev.fpga.lock().unwrap();
            hw.region(vfpga)
                .map_err(|e| HypervisorError::Device(e.to_string()))?
                .shape
                .quarters()
        };
        Ok(crate::hls::flow::DesignFlow::retarget(
            bitfile, slot, quarters,
        ))
    }

    pub fn placement_policy(&self) -> PlacementPolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::tests_support::partial_bs;

    fn hv() -> Hypervisor {
        let clock = VirtualClock::new();
        Hypervisor::boot_paper_testbed(clock).unwrap()
    }

    #[test]
    fn boot_registers_everything() {
        let hv = hv();
        assert_eq!(hv.device_ids().len(), 4);
        let db = hv.db.lock().unwrap();
        assert_eq!(db.devices.len(), 4);
        // 4 devices x 4 vFPGAs.
        let total_regions: usize =
            db.devices.values().map(|d| d.regions.len()).sum();
        assert_eq!(total_regions, 16);
    }

    #[test]
    fn boot_charges_configuration_time() {
        let clock = VirtualClock::new();
        let _hv = Hypervisor::boot_paper_testbed(Arc::clone(&clock)).unwrap();
        // 2x VC707 at 28.37 s + 2x ML605 (scaled) — well over 80 s.
        assert!(clock.now().as_secs_f64() > 80.0);
    }

    #[test]
    fn adopt_vfpga_restores_lease_machinery() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (fpga, node) = hv
            .adopt_vfpga(AllocationId(42), user, ServiceModel::RAaaS, VfpgaId(1))
            .unwrap();
        // Same machinery a fresh allocation gets: DB row under the
        // original id, clock domain, device files, Reserved lifecycle.
        {
            let db = hv.db.lock().unwrap();
            let a = db.allocation(AllocationId(42)).unwrap();
            assert_eq!(a.user, user);
            assert_eq!(db.owner_of(VfpgaId(1)).unwrap().id, AllocationId(42));
        }
        let fifo = crate::pcie::devfile::DeviceFileRegistry::vfpga_path(
            VfpgaId(1),
            crate::pcie::devfile::DeviceFileKind::FifoIn,
            0,
        );
        assert!(hv.registry(node).unwrap().paths().contains(&fifo));
        let dev = hv.device(fpga).unwrap();
        assert_eq!(
            dev.fpga.lock().unwrap().region(VfpgaId(1)).unwrap().lifecycle,
            LifecycleState::Reserved
        );
        // Double adoption of the same region is rejected.
        assert!(hv
            .adopt_vfpga(AllocationId(43), user, ServiceModel::RAaaS, VfpgaId(1))
            .is_err());
        // And the adopted lease releases like any other.
        hv.release(AllocationId(42)).unwrap();
    }

    #[test]
    fn vfpga_allocation_consolidates() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (_, _, f0, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let (_, _, f1, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        // Consolidate-first: same device until full.
        assert_eq!(f0, f1);
    }

    #[test]
    fn allocation_creates_device_files() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (_, vfpga, _, node) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let reg = hv.registry(node).unwrap();
        let path = crate::pcie::devfile::DeviceFileRegistry::vfpga_path(
            vfpga,
            crate::pcie::devfile::DeviceFileKind::FifoIn,
            0,
        );
        assert!(reg.open(&path, Some(user)).is_ok());
    }

    #[test]
    fn alloc_vfpga_on_claims_the_exact_region() {
        let hv = hv();
        let user = hv.add_user("gang");
        let target = {
            let db = hv.db.lock().unwrap();
            db.free_regions(FpgaId(1))[2]
        };
        let (alloc, v, f, _) = hv
            .alloc_vfpga_on(user, ServiceModel::RAaaS, target)
            .unwrap();
        assert_eq!(v, target);
        assert_eq!(f, FpgaId(1));
        // Claiming an already-taken region is the race the gang
        // rollback handles — surfaced as NoCapacity.
        assert!(matches!(
            hv.alloc_vfpga_on(user, ServiceModel::RAaaS, target),
            Err(HypervisorError::NoCapacity)
        ));
        hv.release(alloc).unwrap();
        assert!(hv
            .alloc_vfpga_on(user, ServiceModel::RAaaS, target)
            .is_ok());
    }

    #[test]
    fn capacity_exhausts_at_16() {
        let hv = hv();
        let user = hv.add_user("greedy");
        for _ in 0..16 {
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        }
        assert!(matches!(
            hv.alloc_vfpga(user, ServiceModel::RAaaS),
            Err(HypervisorError::NoCapacity)
        ));
    }

    #[test]
    fn program_and_release_lifecycle() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, vfpga, fpga, node) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        let bs = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "matmul16",
        )
        .resources(crate::fpga::resources::Resources::new(
            25_298, 41_654, 14, 80,
        ))
        .frames(crate::hls::flow::region_window(slot, 1))
        .artifact("matmul16_b256")
        .build();
        let d = hv.program_vfpga(alloc, user, &bs).unwrap();
        // PR (732 ms) + orchestration (111 ms).
        assert!((d.as_millis_f64() - 843.0).abs() < 1.0, "{d}");
        assert!(hv.programmed_bitstream(vfpga).is_some());
        hv.release(alloc).unwrap();
        assert!(hv.programmed_bitstream(vfpga).is_none());
        // Device files are gone.
        let reg = hv.registry(node).unwrap();
        let path = crate::pcie::devfile::DeviceFileRegistry::vfpga_path(
            vfpga,
            crate::pcie::devfile::DeviceFileKind::FifoIn,
            0,
        );
        assert!(reg.open(&path, Some(user)).is_err());
    }

    #[test]
    fn program_rejects_wrong_user() {
        let hv = hv();
        let alice = hv.add_user("alice");
        let mallory = hv.add_user("mallory");
        let (alloc, _, _, _) =
            hv.alloc_vfpga(alice, ServiceModel::RAaaS).unwrap();
        let bs = partial_bs("xc7vx485t", "evil");
        assert!(matches!(
            hv.program_vfpga(alloc, mallory, &bs),
            Err(HypervisorError::BadAllocation(_))
        ));
    }

    #[test]
    fn program_rejects_frame_escape() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, vfpga, fpga, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        // Claim frames of the NEIGHBORING slot.
        let bs = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "evil",
        )
        .resources(crate::fpga::resources::Resources::new(1, 1, 1, 1))
        .frames(crate::hls::flow::region_window((slot + 1) % 4, 1))
        .build();
        assert!(matches!(
            hv.program_vfpga(alloc, user, &bs),
            Err(HypervisorError::Sanity(_))
        ));
    }

    #[test]
    fn status_local_is_11ms() {
        let hv = hv();
        let t0 = hv.clock.now();
        let st = hv.status_local(FpgaId(0)).unwrap();
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!(
            (ms - crate::paper::STATUS_LOCAL_MS).abs() < 0.01,
            "status took {ms} ms"
        );
        assert_eq!(st.regions_total, 4);
    }

    #[test]
    fn rsaas_takes_whole_device() {
        // Config where one device offers RSaaS.
        let clock = VirtualClock::new();
        let hv = Hypervisor::boot(
            &ClusterConfig::single_vc707(),
            clock,
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap();
        let user = hv.add_user("rs");
        let (alloc, fpga, _) = hv.alloc_physical(user, None).unwrap();
        // vFPGA allocation on the same device now fails (NoCapacity —
        // the only device is exclusively held).
        assert!(matches!(
            hv.alloc_vfpga(user, ServiceModel::RAaaS),
            Err(HypervisorError::NoCapacity)
        ));
        // Full reconfiguration works for the holder.
        let bs = crate::bitstream::BitstreamBuilder::full(
            "xc7vx485t",
            "user_design",
        )
        .build();
        let d = hv.program_full(alloc, user, &bs).unwrap();
        assert!(d.as_secs_f64() > 28.0);
        let _ = fpga;
        hv.release(alloc).unwrap();
        assert!(hv.alloc_vfpga(user, ServiceModel::RAaaS).is_ok());
    }

    #[test]
    fn energy_rises_with_active_regions() {
        let hv = hv();
        let idle = hv.total_power_w();
        let user = hv.add_user("alice");
        let (alloc, vfpga, fpga, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        let bs = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "matmul16",
        )
        .resources(crate::fpga::resources::Resources::new(1000, 1000, 1, 1))
        .frames(crate::hls::flow::region_window(slot, 1))
        .build();
        hv.program_vfpga(alloc, user, &bs).unwrap();
        assert!(hv.total_power_w() > idle);
        hv.release(alloc).unwrap();
        assert_eq!(hv.total_power_w(), idle);
    }

    #[test]
    fn lifecycle_tracks_hypervisor_operations() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, vfpga, fpga, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let state = |hv: &Hypervisor| {
            hv.device(fpga)
                .unwrap()
                .fpga
                .lock()
                .unwrap()
                .region(vfpga)
                .unwrap()
                .lifecycle
        };
        assert_eq!(state(&hv), LifecycleState::Reserved);
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        let bs = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "matmul16",
        )
        .resources(crate::fpga::resources::Resources::new(100, 100, 1, 1))
        .frames(crate::hls::flow::region_window(slot, 1))
        .build();
        hv.program_vfpga(alloc, user, &bs).unwrap();
        assert_eq!(state(&hv), LifecycleState::Active);
        hv.release(alloc).unwrap();
        assert_eq!(state(&hv), LifecycleState::Free);
        // Every recorded move was legal and the occupancy gauges see
        // the final all-free state.
        let log = hv
            .device(fpga)
            .unwrap()
            .fpga
            .lock()
            .unwrap()
            .transition_log();
        assert!(!log.is_empty());
        assert!(log.iter().all(|r| r.is_legal()));
        assert_eq!(hv.metrics.gauge("region.state.active").get(), 0);
        assert!(hv.metrics.counter("region.transitions").get() >= 4);
    }

    #[test]
    fn failed_program_returns_region_to_reserved() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, vfpga, fpga, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        // Frame-escaping bitfile: rejected by the sanity checker after
        // the region already entered Programming.
        let evil = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "evil",
        )
        .resources(crate::fpga::resources::Resources::new(1, 1, 1, 1))
        .frames(crate::hls::flow::region_window((slot + 1) % 4, 1))
        .build();
        assert!(hv.program_vfpga(alloc, user, &evil).is_err());
        let region_state = hv
            .device(fpga)
            .unwrap()
            .fpga
            .lock()
            .unwrap()
            .region(vfpga)
            .unwrap()
            .lifecycle;
        assert_eq!(region_state, LifecycleState::Reserved);
        // The region is still pinnable and programmable.
        let good = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "good",
        )
        .resources(crate::fpga::resources::Resources::new(1, 1, 1, 1))
        .frames(crate::hls::flow::region_window(slot, 1))
        .build();
        hv.program_vfpga(alloc, user, &good).unwrap();
        assert_eq!(hv.guards().pins(vfpga), 0, "no pin leaked");
    }

    #[test]
    fn baaas_service_registry() {
        let hv = hv();
        assert!(hv.service_bitfile("imgproc").is_err());
        hv.register_service(
            "imgproc",
            partial_bs("xc7vx485t", "imgproc"),
        );
        assert!(hv.service_bitfile("imgproc").is_ok());
        assert_eq!(hv.service_names(), vec!["imgproc".to_string()]);
    }
}
