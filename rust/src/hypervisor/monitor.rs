//! Resource monitoring — "The system includes resource management and
//! monitoring of FPGA resources" (Section IV).
//!
//! The monitor samples every managed device through the same status
//! path the middleware uses (so monitoring load is visible in the
//! latency accounting), maintains utilization/power time series, and
//! renders the operator report the CLI's `rc3e cli monitor` shows.

use std::collections::BTreeMap;

use super::core::Hypervisor;
use crate::util::clock::VirtualTime;
use crate::util::ids::FpgaId;
use crate::util::json::Json;

/// One sample of one device.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub at: VirtualTime,
    pub regions_total: usize,
    pub regions_configured: usize,
    pub regions_clocked: usize,
    pub power_w: f64,
}

/// Aggregated view over a sampling window.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSummary {
    pub fpga: FpgaId,
    pub samples: usize,
    pub mean_configured: f64,
    pub peak_configured: usize,
    pub mean_power_w: f64,
    pub peak_power_w: f64,
    /// Fraction of samples with at least one active region.
    pub busy_fraction: f64,
}

/// The monitoring store.
#[derive(Debug, Default)]
pub struct Monitor {
    series: BTreeMap<FpgaId, Vec<Sample>>,
}

impl Monitor {
    pub fn new() -> Monitor {
        Monitor::default()
    }

    /// Sample every device once (charges the status-call latency per
    /// device, like a real monitoring daemon would).
    pub fn sample_all(&mut self, hv: &Hypervisor) {
        for fpga in hv.device_ids() {
            if let Ok(st) = hv.status_local(fpga) {
                self.series.entry(fpga).or_default().push(Sample {
                    at: hv.clock.now(),
                    regions_total: st.regions_total,
                    regions_configured: st.regions_configured,
                    regions_clocked: st.regions_clocked,
                    power_w: st.power_w,
                });
            }
        }
    }

    pub fn samples(&self, fpga: FpgaId) -> &[Sample] {
        self.series.get(&fpga).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Summaries per device.
    pub fn summaries(&self) -> Vec<DeviceSummary> {
        self.series
            .iter()
            .map(|(fpga, samples)| {
                let n = samples.len().max(1) as f64;
                DeviceSummary {
                    fpga: *fpga,
                    samples: samples.len(),
                    mean_configured: samples
                        .iter()
                        .map(|s| s.regions_configured as f64)
                        .sum::<f64>()
                        / n,
                    peak_configured: samples
                        .iter()
                        .map(|s| s.regions_configured)
                        .max()
                        .unwrap_or(0),
                    mean_power_w: samples
                        .iter()
                        .map(|s| s.power_w)
                        .sum::<f64>()
                        / n,
                    peak_power_w: samples
                        .iter()
                        .map(|s| s.power_w)
                        .fold(0.0, f64::max),
                    busy_fraction: samples
                        .iter()
                        .filter(|s| s.regions_clocked > 0)
                        .count() as f64
                        / n,
                }
            })
            .collect()
    }

    /// Cloud-wide utilization: configured regions / total regions in
    /// the latest sample (the quantity consolidation maximizes).
    pub fn cloud_utilization(&self) -> f64 {
        let (mut configured, mut total) = (0usize, 0usize);
        for samples in self.series.values() {
            if let Some(last) = samples.last() {
                configured += last.regions_configured;
                total += last.regions_total;
            }
        }
        if total == 0 {
            0.0
        } else {
            configured as f64 / total as f64
        }
    }

    /// Operator report (JSON, served by the middleware's `monitor`
    /// method).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.summaries()
                .into_iter()
                .map(|s| {
                    Json::obj(vec![
                        ("fpga", Json::from(s.fpga.to_string())),
                        ("samples", Json::from(s.samples)),
                        (
                            "mean_configured",
                            Json::from(s.mean_configured),
                        ),
                        (
                            "peak_configured",
                            Json::from(s.peak_configured),
                        ),
                        ("mean_power_w", Json::from(s.mean_power_w)),
                        ("peak_power_w", Json::from(s.peak_power_w)),
                        ("busy_fraction", Json::from(s.busy_fraction)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceModel;
    use crate::hypervisor::PlacementPolicy;
    use crate::util::clock::VirtualClock;

    fn hv() -> Hypervisor {
        Hypervisor::boot(
            &crate::config::ClusterConfig::paper_testbed(),
            VirtualClock::new(),
            PlacementPolicy::ConsolidateFirst,
        )
        .unwrap()
    }

    fn program_one(hv: &Hypervisor) -> crate::util::ids::AllocationId {
        let user = hv.add_user("mon");
        let (alloc, vfpga, fpga, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        let part =
            hv.device(fpga).unwrap().fpga.lock().unwrap().board.part;
        let bs = crate::bitstream::BitstreamBuilder::partial(part, "m")
            .resources(crate::fpga::Resources::new(10, 10, 1, 1))
            .frames(crate::hls::flow::region_window(slot, 1))
            .build();
        hv.program_vfpga(alloc, user, &bs).unwrap();
        alloc
    }

    #[test]
    fn sampling_builds_series() {
        let hv = hv();
        let mut mon = Monitor::new();
        mon.sample_all(&hv);
        mon.sample_all(&hv);
        for fpga in hv.device_ids() {
            assert_eq!(mon.samples(fpga).len(), 2);
        }
    }

    #[test]
    fn sampling_charges_status_latency() {
        let hv = hv();
        let mut mon = Monitor::new();
        let t0 = hv.clock.now();
        mon.sample_all(&hv);
        // 4 devices x ~11 ms local status.
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!((ms - 44.0).abs() < 0.5, "{ms}");
    }

    #[test]
    fn utilization_tracks_configuration() {
        let hv = hv();
        let mut mon = Monitor::new();
        mon.sample_all(&hv);
        assert_eq!(mon.cloud_utilization(), 0.0);
        let alloc = program_one(&hv);
        mon.sample_all(&hv);
        assert!((mon.cloud_utilization() - 1.0 / 16.0).abs() < 1e-9);
        hv.release(alloc).unwrap();
        mon.sample_all(&hv);
        assert_eq!(mon.cloud_utilization(), 0.0);
    }

    #[test]
    fn summaries_capture_peaks() {
        let hv = hv();
        let mut mon = Monitor::new();
        mon.sample_all(&hv); // idle
        let alloc = program_one(&hv);
        mon.sample_all(&hv); // busy
        hv.release(alloc).unwrap();
        mon.sample_all(&hv); // idle again
        let summaries = mon.summaries();
        let busy = summaries
            .iter()
            .find(|s| s.peak_configured == 1)
            .expect("one device saw a configured region");
        assert_eq!(busy.samples, 3);
        assert!(busy.busy_fraction > 0.0 && busy.busy_fraction < 1.0);
        assert!(busy.peak_power_w > busy.mean_power_w);
    }

    #[test]
    fn json_report_shape() {
        let hv = hv();
        let mut mon = Monitor::new();
        mon.sample_all(&hv);
        let j = mon.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        assert!(arr[0].get("mean_power_w").as_f64().is_some());
    }
}
