//! Design migration between vFPGA regions — a paper future-work item
//! ("A migration of user designs between vFPGAs and physical FPGAs is
//! also intended", Section VI), implemented as a first-class feature.
//!
//! Procedure (cold migration, the user's stream is quiesced):
//! 1. pick a target region on another (or the same) device via the
//!    placement policy;
//! 2. retarget the relocatable partial bitfile to the target slot's
//!    frame window ([`crate::hls::flow::DesignFlow::retarget`]);
//! 3. PR the target region (sanity-checked like any PR);
//! 4. rebind the lease in the database, move the device files,
//!    blank the source region and gate its clock.

use super::core::{Hypervisor, HypervisorError};
use super::db::AllocKind;
use crate::hls::flow::DesignFlow;
use crate::util::clock::VirtualTime;
use crate::util::ids::{AllocationId, UserId, VfpgaId};

/// Outcome of a migration.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationReport {
    pub from: VfpgaId,
    pub to: VfpgaId,
    pub moved_across_devices: bool,
    /// Total virtual downtime (PR + orchestration).
    pub downtime: VirtualTime,
}

impl Hypervisor {
    /// Migrate a configured vFPGA lease to a new region. `prefer`
    /// optionally pins the target region; otherwise the placement
    /// policy chooses among free regions on *other* devices first.
    pub fn migrate_vfpga(
        &self,
        alloc_id: AllocationId,
        user: UserId,
        prefer: Option<VfpgaId>,
    ) -> Result<MigrationReport, HypervisorError> {
        let source = self.check_vfpga_lease(alloc_id, user)?;
        let bitstream = self
            .programmed_bitstream(source)
            .ok_or(HypervisorError::WrongKind(alloc_id))?;

        // -------- choose target ---------------------------------
        let (src_fpga, target) = {
            let db = self.db.lock().unwrap();
            let src_fpga = db
                .device_of_vfpga(source)
                .ok_or(HypervisorError::BadAllocation(alloc_id))?
                .id;
            let target = match prefer {
                Some(t) => t,
                None => {
                    // Free regions on other devices *serving the
                    // lease's service model* first, then the same
                    // device (deterministic order) — relocation must
                    // respect the per-device model policy that
                    // alloc_vfpga enforces.
                    let model = db
                        .allocation(alloc_id)
                        .map(|a| a.model)
                        .ok_or(HypervisorError::BadAllocation(alloc_id))?;
                    let mut candidates: Vec<VfpgaId> = Vec::new();
                    for (id, entry) in self.db_devices(&db) {
                        if id != src_fpga && entry.models.contains(&model)
                        {
                            candidates.extend(db.free_regions(id));
                        }
                    }
                    candidates.extend(db.free_regions(src_fpga));
                    *candidates
                        .first()
                        .ok_or(HypervisorError::NoCapacity)?
                }
            };
            if db.owner_of(target).is_some() || target == source {
                return Err(HypervisorError::NoCapacity);
            }
            (src_fpga, target)
        };

        let t0 = self.clock.now();
        let (dst_fpga, dst_node) = {
            let db = self.db.lock().unwrap();
            let d = db
                .device_of_vfpga(target)
                .ok_or(HypervisorError::NoCapacity)?;
            (d.id, d.node)
        };
        let dst_dev = self.device(dst_fpga)?;
        let dst_slot = dst_dev.slot_of[&target];
        let dst_quarters = {
            let hw = dst_dev.fpga.lock().unwrap();
            hw.region(target)
                .map_err(|e| HypervisorError::Device(e.to_string()))?
                .shape
                .quarters()
        };

        // -------- retarget + rebind lease ------------------------
        let moved = DesignFlow::retarget(&bitstream, dst_slot, dst_quarters);
        {
            // Rebind in the database: swap the vfpga inside the
            // existing allocation record.
            let mut db = self.db.lock().unwrap();
            let alloc = db
                .allocations
                .get_mut(&alloc_id)
                .ok_or(HypervisorError::BadAllocation(alloc_id))?;
            alloc.kind = AllocKind::Vfpga(target);
            db.vfpga_owner.remove(&source);
            db.vfpga_owner.insert(target, alloc_id);
        }
        dst_dev
            .controller
            .lock()
            .unwrap()
            .allocate(target, user)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.registries_of(dst_node)
            .create_vfpga_files(target, user)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;

        // -------- program target (sanity-checked PR) -------------
        let program_result = self.program_vfpga(alloc_id, user, &moved);
        if let Err(e) = program_result {
            // Roll back the rebind so the lease still points at the
            // (still configured) source region.
            let mut db = self.db.lock().unwrap();
            if let Some(alloc) = db.allocations.get_mut(&alloc_id) {
                alloc.kind = AllocKind::Vfpga(source);
            }
            db.vfpga_owner.remove(&target);
            db.vfpga_owner.insert(source, alloc_id);
            drop(db);
            self.registries_of(dst_node).remove_vfpga_files(target);
            let _ = dst_dev.controller.lock().unwrap().release(target);
            return Err(e);
        }

        // -------- blank the source ------------------------------
        let (src_node, src_dev_id) = {
            let db = self.db.lock().unwrap();
            // device_of_vfpga no longer finds `source` via ownership —
            // look through device entries directly.
            let d = db
                .devices
                .values()
                .find(|d| d.regions.contains(&source))
                .ok_or(HypervisorError::NoCapacity)?;
            (d.node, d.id)
        };
        let src_dev = self.device(src_dev_id)?;
        src_dev
            .fpga
            .lock()
            .unwrap()
            .clear_region(source)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        src_dev
            .controller
            .lock()
            .unwrap()
            .release(source)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.registries_of(src_node).remove_vfpga_files(source);

        self.metrics.counter("hv.migrations").inc();
        Ok(MigrationReport {
            from: source,
            to: target,
            moved_across_devices: src_fpga != dst_fpga,
            downtime: self.clock.since(t0),
        })
    }

    fn db_devices<'a>(
        &self,
        db: &'a crate::hypervisor::db::DeviceDb,
    ) -> Vec<(crate::util::ids::FpgaId, &'a crate::hypervisor::db::DeviceEntry)>
    {
        db.devices.iter().map(|(id, e)| (*id, e)).collect()
    }

    fn registries_of(
        &self,
        node: crate::util::ids::NodeId,
    ) -> &crate::pcie::devfile::DeviceFileRegistry {
        self.registry(node).expect("node registry").as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceModel;
    use crate::util::clock::VirtualClock;

    fn hv() -> Hypervisor {
        Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap()
    }

    fn programmed_lease(
        hv: &Hypervisor,
        user: UserId,
    ) -> (AllocationId, VfpgaId, crate::util::ids::FpgaId) {
        let (alloc, vfpga, fpga, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        let bs = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "matmul16",
        )
        .resources(crate::fpga::resources::Resources::new(
            25_298, 41_654, 14, 80,
        ))
        .frames(crate::hls::flow::region_window(slot, 1))
        .artifact("matmul16_b256")
        .build();
        hv.program_vfpga(alloc, user, &bs).unwrap();
        (alloc, vfpga, fpga)
    }

    #[test]
    fn migration_moves_design_across_devices() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, source, src_fpga) = programmed_lease(&hv, user);
        let report = hv.migrate_vfpga(alloc, user, None).unwrap();
        assert_eq!(report.from, source);
        assert_ne!(report.to, source);
        assert!(report.moved_across_devices);
        // Lease now points at the target.
        assert_eq!(hv.check_vfpga_lease(alloc, user).unwrap(), report.to);
        // Target is configured with the same core; source blanked.
        let db = hv.db.lock().unwrap();
        let dst_fpga = db.device_of_vfpga(report.to).unwrap().id;
        drop(db);
        let dst = hv.device(dst_fpga).unwrap();
        let hw = dst.fpga.lock().unwrap();
        assert!(hw.region(report.to).unwrap().is_configured());
        drop(hw);
        let src = hv.device(src_fpga).unwrap();
        assert!(!src
            .fpga
            .lock()
            .unwrap()
            .region(source)
            .unwrap()
            .is_configured());
        // Downtime ≈ PR + orchestration.
        assert!(report.downtime.as_millis_f64() > 700.0);
    }

    #[test]
    fn migration_to_pinned_target() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, source, src_fpga) = programmed_lease(&hv, user);
        // Pin to a free region on the same device.
        let target = {
            let db = hv.db.lock().unwrap();
            db.free_regions(src_fpga)[0]
        };
        let report = hv.migrate_vfpga(alloc, user, Some(target)).unwrap();
        assert_eq!(report.to, target);
        assert!(!report.moved_across_devices);
        assert_ne!(report.from, report.to);
        let _ = source;
    }

    #[test]
    fn migration_requires_configured_design() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, _, _, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        // Never programmed → nothing to migrate.
        assert!(hv.migrate_vfpga(alloc, user, None).is_err());
    }

    #[test]
    fn migration_rejects_taken_target() {
        let hv = hv();
        let alice = hv.add_user("alice");
        let bob = hv.add_user("bob");
        let (alloc_a, _, _) = programmed_lease(&hv, alice);
        let (_, vfpga_b, _, _) =
            hv.alloc_vfpga(bob, ServiceModel::RAaaS).unwrap();
        assert!(matches!(
            hv.migrate_vfpga(alloc_a, alice, Some(vfpga_b)),
            Err(HypervisorError::NoCapacity)
        ));
    }

    #[test]
    fn migrated_files_follow_the_lease() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, source, _) = programmed_lease(&hv, user);
        let report = hv.migrate_vfpga(alloc, user, None).unwrap();
        let db = hv.db.lock().unwrap();
        let dst_node = db.device_of_vfpga(report.to).unwrap().node;
        drop(db);
        let reg = hv.registry(dst_node).unwrap();
        let new_path = crate::pcie::devfile::DeviceFileRegistry::vfpga_path(
            report.to,
            crate::pcie::devfile::DeviceFileKind::FifoIn,
            0,
        );
        assert!(reg.open(&new_path, Some(user)).is_ok());
        let old_path = crate::pcie::devfile::DeviceFileRegistry::vfpga_path(
            source,
            crate::pcie::devfile::DeviceFileKind::FifoIn,
            0,
        );
        // Old files removed on every node.
        for node in [0u64, 1] {
            if let Some(r) = hv.registry(crate::util::ids::NodeId(node)) {
                assert!(r.open(&old_path, Some(user)).is_err());
            }
        }
    }
}
