//! Design migration between vFPGA regions — a paper future-work item
//! ("A migration of user designs between vFPGAs and physical FPGAs is
//! also intended", Section VI), implemented as a first-class feature.
//!
//! Procedure (cold migration — quiesce-based since the lifecycle
//! refactor):
//! 1. **win a quiesce** on the lease's current region
//!    ([`crate::hypervisor::guard`]): in-flight setup/stream pins
//!    drain first, so a migration can never observe a region
//!    mid-`Programming` — the race the scheduler used to absorb with
//!    a retry is structurally impossible;
//! 2. mark the source `Draining`, pick a target region on another (or
//!    the same) device via the placement policy;
//! 3. retarget the relocatable partial bitfile to the target slot's
//!    frame window ([`crate::hls::flow::DesignFlow::retarget`]);
//! 4. mark the source `Migrating`, rebind the lease in the database,
//!    PR the target region (sanity-checked like any PR — the target
//!    walks `Reserved -> Programming -> Active`);
//! 5. blank the source (`Migrating -> Free`), move the device files.
//!
//! On a failed target PR everything rolls back: the lease re-binds to
//! the still-configured source, which returns `Migrating -> Active`.

use super::core::{Hypervisor, HypervisorError};
use super::db::AllocKind;
use super::guard::QuiesceGuard;
use crate::fpga::lifecycle::LifecycleState;
use crate::hls::flow::DesignFlow;
use crate::util::clock::VirtualTime;
use crate::util::ids::{AllocationId, UserId, VfpgaId};

/// Outcome of a migration.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationReport {
    pub from: VfpgaId,
    pub to: VfpgaId,
    pub moved_across_devices: bool,
    /// Total virtual downtime (PR + orchestration).
    pub downtime: VirtualTime,
}

impl Hypervisor {
    /// Migrate a configured vFPGA lease to a new region. `prefer`
    /// optionally pins the target region; otherwise the placement
    /// policy chooses among free regions on *other* devices first.
    ///
    /// Blocks until the region quiesce is won (pins drained); the
    /// wall wait lands in the `sched.preempt.quiesce_wait` histogram.
    /// The scheduler's preemption path instead pre-wins a
    /// non-blocking quiesce and calls [`Self::migrate_quiesced`]
    /// directly, skipping busy victims rather than waiting on them.
    pub fn migrate_vfpga(
        &self,
        alloc_id: AllocationId,
        user: UserId,
        prefer: Option<VfpgaId>,
    ) -> Result<MigrationReport, HypervisorError> {
        // Re-resolve after winning: a concurrent relocation may have
        // moved the lease while we waited for the quiesce.
        let guard = loop {
            let source = self.check_vfpga_lease(alloc_id, user)?;
            let guard = self.quiesce_region(source);
            if self.check_vfpga_lease(alloc_id, user)? == source {
                break guard;
            }
        };
        self.migrate_quiesced(alloc_id, user, prefer, guard)
    }

    /// Migration proper, under an already-won quiesce of the lease's
    /// current region. The guard is held for the whole relocation and
    /// released on return (success or failure).
    pub fn migrate_quiesced(
        &self,
        alloc_id: AllocationId,
        user: UserId,
        prefer: Option<VfpgaId>,
        guard: QuiesceGuard,
    ) -> Result<MigrationReport, HypervisorError> {
        let source = guard.region();
        if self.check_vfpga_lease(alloc_id, user)? != source {
            // The guard covers a region this lease no longer holds
            // (it was relocated before the caller won the quiesce).
            return Err(HypervisorError::NoCapacity);
        }
        let bitstream = self
            .programmed_bitstream(source)
            .ok_or(HypervisorError::WrongKind(alloc_id))?;

        // -------- choose target ---------------------------------
        let (src_fpga, target) = {
            let db = self.db.lock().unwrap();
            let src_fpga = db
                .device_of_vfpga(source)
                .ok_or(HypervisorError::BadAllocation(alloc_id))?
                .id;
            let target = match prefer {
                Some(t) => t,
                None => {
                    // Free regions on other devices *serving the
                    // lease's service model* first, then the same
                    // device (deterministic order) — relocation must
                    // respect the per-device model policy that
                    // alloc_vfpga enforces.
                    let model = db
                        .allocation(alloc_id)
                        .map(|a| a.model)
                        .ok_or(HypervisorError::BadAllocation(alloc_id))?;
                    let mut candidates: Vec<VfpgaId> = Vec::new();
                    for (id, entry) in self.db_devices(&db) {
                        if id != src_fpga && entry.models.contains(&model)
                        {
                            candidates.extend(db.free_regions(id));
                        }
                    }
                    candidates.extend(db.free_regions(src_fpga));
                    *candidates
                        .first()
                        .ok_or(HypervisorError::NoCapacity)?
                }
            };
            if db.owner_of(target).is_some() || target == source {
                return Err(HypervisorError::NoCapacity);
            }
            (src_fpga, target)
        };

        let src_dev = self.device(src_fpga)?;
        // The quiesce is won: the source leaves Active for Draining —
        // this is where "a migration can never observe Programming"
        // is enforced by type, not by retry.
        src_dev
            .fpga
            .lock()
            .unwrap()
            .transition_region(source, LifecycleState::Draining)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;

        let t0 = self.clock.now();
        let dst = {
            let db = self.db.lock().unwrap();
            db.device_of_vfpga(target).map(|d| (d.id, d.node))
        };
        let Some((dst_fpga, dst_node)) = dst else {
            self.abort_drain(src_fpga, source);
            return Err(HypervisorError::NoCapacity);
        };
        let dst_dev = match self.device(dst_fpga) {
            Ok(d) => d,
            Err(e) => {
                self.abort_drain(src_fpga, source);
                return Err(e);
            }
        };
        let dst_slot = dst_dev.slot_of[&target];
        let dst_quarters = {
            let quarters = dst_dev
                .fpga
                .lock()
                .unwrap()
                .region(target)
                .map(|r| r.shape.quarters());
            match quarters {
                Ok(q) => q,
                Err(e) => {
                    self.abort_drain(src_fpga, source);
                    return Err(HypervisorError::Device(e.to_string()));
                }
            }
        };

        // -------- retarget + rebind lease ------------------------
        let moved = DesignFlow::retarget(&bitstream, dst_slot, dst_quarters);
        // Quiesce the *target* too for the whole relocation: the
        // moment the lease is rebound below, its owner's pin_current
        // resolves the target — the quiesce parks that pin until the
        // target is programmed, so the owner can never stream or
        // program a half-migrated region. (The PR below uses the
        // pinless `program_vfpga_at`: taking a pin here would block
        // on our own guard.)
        let Some(_target_guard) = self.guards().try_quiesce(target)
        else {
            // Someone is mid-operation on a region the DB called
            // free — treat as a lost race.
            self.abort_drain(src_fpga, source);
            return Err(HypervisorError::NoCapacity);
        };
        src_dev
            .fpga
            .lock()
            .unwrap()
            .transition_region(source, LifecycleState::Migrating)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        {
            // Rebind in the database: swap the vfpga inside the
            // existing allocation record. Re-validate the target
            // under this lock — a racing allocation may have claimed
            // it since the candidate snapshot.
            let mut db = self.db.lock().unwrap();
            if db.vfpga_owner.contains_key(&target) {
                drop(db);
                let _ = src_dev
                    .fpga
                    .lock()
                    .unwrap()
                    .transition_region(source, LifecycleState::Active);
                return Err(HypervisorError::NoCapacity);
            }
            let alloc = db
                .allocations
                .get_mut(&alloc_id)
                .ok_or(HypervisorError::BadAllocation(alloc_id))?;
            alloc.kind = AllocKind::Vfpga(target);
            db.vfpga_owner.remove(&source);
            db.vfpga_owner.insert(target, alloc_id);
        }
        dst_dev
            .controller
            .lock()
            .unwrap()
            .allocate(target, user)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.registries_of(dst_node)
            .create_vfpga_files(target, user)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;
        // The target is claimed: Free -> Reserved; programming below
        // drives it Reserved -> Programming -> Active.
        dst_dev
            .fpga
            .lock()
            .unwrap()
            .transition_region(target, LifecycleState::Reserved)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;

        // -------- program target (sanity-checked PR) -------------
        // Pinless variant: the target quiesce above is the exclusion.
        let program_result = self.program_vfpga_at(target, &moved);
        if let Err(e) = program_result {
            // Roll back the rebind so the lease still points at the
            // (still configured) source region.
            let lease_alive = {
                let mut db = self.db.lock().unwrap();
                let alive = match db.allocations.get_mut(&alloc_id) {
                    Some(alloc) => {
                        alloc.kind = AllocKind::Vfpga(source);
                        true
                    }
                    // Released out from under us while rebound: do
                    // not resurrect ownership of the source.
                    None => false,
                };
                db.vfpga_owner.remove(&target);
                if alive {
                    db.vfpga_owner.insert(source, alloc_id);
                }
                alive
            };
            self.registries_of(dst_node).remove_vfpga_files(target);
            let _ = dst_dev.controller.lock().unwrap().release(target);
            let _ = dst_dev
                .fpga
                .lock()
                .unwrap()
                .transition_region(target, LifecycleState::Free);
            if lease_alive {
                // The design never left the source:
                // Migrating -> Active.
                let _ = src_dev
                    .fpga
                    .lock()
                    .unwrap()
                    .transition_region(source, LifecycleState::Active);
            } else {
                // The lease was released mid-rebind: nobody owns the
                // source design any more — blank it so the region is
                // genuinely reusable, and drop its leftovers.
                let _ =
                    src_dev.fpga.lock().unwrap().clear_region(source);
                let _ =
                    src_dev.controller.lock().unwrap().release(source);
                if let Some(src_node) = {
                    let db = self.db.lock().unwrap();
                    db.device(src_fpga).map(|d| d.node)
                } {
                    self.registries_of(src_node)
                        .remove_vfpga_files(source);
                }
                self.forget_programmed(source);
            }
            self.refresh_region_gauges();
            return Err(e);
        }

        // -------- blank the source ------------------------------
        let src_node = {
            let db = self.db.lock().unwrap();
            db.device(src_fpga)
                .ok_or(HypervisorError::NoCapacity)?
                .node
        };
        src_dev
            .fpga
            .lock()
            .unwrap()
            .clear_region(source)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        src_dev
            .controller
            .lock()
            .unwrap()
            .release(source)
            .map_err(|e| HypervisorError::Device(e.to_string()))?;
        self.registries_of(src_node).remove_vfpga_files(source);
        // The design now lives at the target; the source's programmed
        // record must not outlive its tenancy.
        self.forget_programmed(source);

        self.metrics.counter("hv.migrations").inc();
        self.refresh_region_gauges();
        Ok(MigrationReport {
            from: source,
            to: target,
            moved_across_devices: src_fpga != dst_fpga,
            downtime: self.clock.since(t0),
        })
    }

    /// Undo a `Draining` mark on an aborted (pre-`Migrating`)
    /// relocation.
    fn abort_drain(&self, src_fpga: crate::util::ids::FpgaId, source: VfpgaId) {
        if let Ok(dev) = self.device(src_fpga) {
            let _ = dev
                .fpga
                .lock()
                .unwrap()
                .transition_region(source, LifecycleState::Active);
        }
    }

    fn db_devices<'a>(
        &self,
        db: &'a crate::hypervisor::db::DeviceDb,
    ) -> Vec<(crate::util::ids::FpgaId, &'a crate::hypervisor::db::DeviceEntry)>
    {
        db.devices.iter().map(|(id, e)| (*id, e)).collect()
    }

    fn registries_of(
        &self,
        node: crate::util::ids::NodeId,
    ) -> &crate::pcie::devfile::DeviceFileRegistry {
        self.registry(node).expect("node registry").as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceModel;
    use crate::util::clock::VirtualClock;

    fn hv() -> Hypervisor {
        Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap()
    }

    fn programmed_lease(
        hv: &Hypervisor,
        user: UserId,
    ) -> (AllocationId, VfpgaId, crate::util::ids::FpgaId) {
        let (alloc, vfpga, fpga, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        let slot = hv.device(fpga).unwrap().slot_of[&vfpga];
        let bs = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            "matmul16",
        )
        .resources(crate::fpga::resources::Resources::new(
            25_298, 41_654, 14, 80,
        ))
        .frames(crate::hls::flow::region_window(slot, 1))
        .artifact("matmul16_b256")
        .build();
        hv.program_vfpga(alloc, user, &bs).unwrap();
        (alloc, vfpga, fpga)
    }

    #[test]
    fn migration_moves_design_across_devices() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, source, src_fpga) = programmed_lease(&hv, user);
        let report = hv.migrate_vfpga(alloc, user, None).unwrap();
        assert_eq!(report.from, source);
        assert_ne!(report.to, source);
        assert!(report.moved_across_devices);
        // Lease now points at the target.
        assert_eq!(hv.check_vfpga_lease(alloc, user).unwrap(), report.to);
        // Target is configured with the same core; source blanked.
        let db = hv.db.lock().unwrap();
        let dst_fpga = db.device_of_vfpga(report.to).unwrap().id;
        drop(db);
        let dst = hv.device(dst_fpga).unwrap();
        let hw = dst.fpga.lock().unwrap();
        assert!(hw.region(report.to).unwrap().is_configured());
        drop(hw);
        let src = hv.device(src_fpga).unwrap();
        assert!(!src
            .fpga
            .lock()
            .unwrap()
            .region(source)
            .unwrap()
            .is_configured());
        // Downtime ≈ PR + orchestration.
        assert!(report.downtime.as_millis_f64() > 700.0);
    }

    #[test]
    fn migration_walks_the_lifecycle() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, source, src_fpga) = programmed_lease(&hv, user);
        let report = hv.migrate_vfpga(alloc, user, None).unwrap();
        // Source: ... Active -> Draining -> Migrating -> Free.
        let src_log = hv
            .device(src_fpga)
            .unwrap()
            .fpga
            .lock()
            .unwrap()
            .transition_log();
        let src_moves: Vec<(LifecycleState, LifecycleState)> = src_log
            .iter()
            .filter(|r| r.region == source)
            .map(|r| (r.from, r.to))
            .collect();
        assert!(src_moves.contains(&(
            LifecycleState::Active,
            LifecycleState::Draining
        )));
        assert!(src_moves.contains(&(
            LifecycleState::Draining,
            LifecycleState::Migrating
        )));
        assert!(src_moves.contains(&(
            LifecycleState::Migrating,
            LifecycleState::Free
        )));
        // A migration never sees Programming on the source: no
        // source-region Programming record between Draining and Free.
        let drain_idx = src_moves
            .iter()
            .position(|m| m.1 == LifecycleState::Draining)
            .unwrap();
        assert!(src_moves[drain_idx..]
            .iter()
            .all(|m| m.1 != LifecycleState::Programming));
        // Target ends Active; every record everywhere is legal.
        let db = hv.db.lock().unwrap();
        let dst_fpga = db.device_of_vfpga(report.to).unwrap().id;
        drop(db);
        let dst_hw = hv.device(dst_fpga).unwrap().fpga.lock().unwrap();
        assert_eq!(
            dst_hw.region(report.to).unwrap().lifecycle,
            LifecycleState::Active
        );
        assert!(dst_hw.transition_log().iter().all(|r| r.is_legal()));
    }

    #[test]
    fn migration_waits_out_a_pinned_region() {
        let hv = std::sync::Arc::new(hv());
        let user = hv.add_user("alice");
        let (alloc, source, _) = programmed_lease(&hv, user);
        // A worker holds a pin (simulating in-flight setup/stream).
        let pin = hv.guards().pin(source);
        let hv2 = std::sync::Arc::clone(&hv);
        let migrator = std::thread::spawn(move || {
            hv2.migrate_vfpga(alloc, user, None)
        });
        // The migration parks on the quiesce; the lease stays put.
        std::thread::sleep(std::time::Duration::from_millis(40));
        assert_eq!(hv.check_vfpga_lease(alloc, user).unwrap(), source);
        drop(pin);
        let report = migrator.join().unwrap().unwrap();
        assert_eq!(report.from, source);
        assert_ne!(report.to, source);
        // The quiesce acquisition is on record.
        assert!(
            hv.metrics
                .histogram("sched.preempt.quiesce_wait")
                .count()
                >= 1
        );
    }

    #[test]
    fn migration_to_pinned_target() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, source, src_fpga) = programmed_lease(&hv, user);
        // Pin to a free region on the same device.
        let target = {
            let db = hv.db.lock().unwrap();
            db.free_regions(src_fpga)[0]
        };
        let report = hv.migrate_vfpga(alloc, user, Some(target)).unwrap();
        assert_eq!(report.to, target);
        assert!(!report.moved_across_devices);
        assert_ne!(report.from, report.to);
        let _ = source;
    }

    #[test]
    fn migration_requires_configured_design() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, _, _, _) =
            hv.alloc_vfpga(user, ServiceModel::RAaaS).unwrap();
        // Never programmed → nothing to migrate.
        assert!(hv.migrate_vfpga(alloc, user, None).is_err());
    }

    #[test]
    fn migration_rejects_taken_target() {
        let hv = hv();
        let alice = hv.add_user("alice");
        let bob = hv.add_user("bob");
        let (alloc_a, source_a, src_fpga) = programmed_lease(&hv, alice);
        let (_, vfpga_b, _, _) =
            hv.alloc_vfpga(bob, ServiceModel::RAaaS).unwrap();
        assert!(matches!(
            hv.migrate_vfpga(alloc_a, alice, Some(vfpga_b)),
            Err(HypervisorError::NoCapacity)
        ));
        // The rejected migration left the source running (Active) and
        // released its quiesce.
        let hw = hv.device(src_fpga).unwrap().fpga.lock().unwrap();
        assert_eq!(
            hw.region(source_a).unwrap().lifecycle,
            LifecycleState::Active
        );
        drop(hw);
        assert!(hv.guards().is_quiescable(source_a));
    }

    #[test]
    fn migrated_files_follow_the_lease() {
        let hv = hv();
        let user = hv.add_user("alice");
        let (alloc, source, _) = programmed_lease(&hv, user);
        let report = hv.migrate_vfpga(alloc, user, None).unwrap();
        let db = hv.db.lock().unwrap();
        let dst_node = db.device_of_vfpga(report.to).unwrap().node;
        drop(db);
        let reg = hv.registry(dst_node).unwrap();
        let new_path = crate::pcie::devfile::DeviceFileRegistry::vfpga_path(
            report.to,
            crate::pcie::devfile::DeviceFileKind::FifoIn,
            0,
        );
        assert!(reg.open(&new_path, Some(user)).is_ok());
        let old_path = crate::pcie::devfile::DeviceFileRegistry::vfpga_path(
            source,
            crate::pcie::devfile::DeviceFileKind::FifoIn,
            0,
        );
        // Old files removed on every node.
        for node in [0u64, 1] {
            if let Some(r) = hv.registry(crate::util::ids::NodeId(node)) {
                assert!(r.open(&old_path, Some(user)).is_err());
            }
        }
    }
}
