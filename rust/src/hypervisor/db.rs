//! The device database.
//!
//! Section IV-B: "The hypervisor has access to a database containing
//! all physical and virtual FPGA devices in the cloud system and
//! their allocation status. Each device is assigned to its physical
//! host system (node)."
//!
//! The database is the *bookkeeping* view (who holds what); the
//! *device* view (what is actually configured) lives in
//! [`crate::fpga::FpgaDevice`]. Persistence is a pretty-printed JSON
//! file so operators can inspect it (and tests diff it).

use std::collections::BTreeMap;

use crate::config::ServiceModel;
use crate::fpga::board::BoardKind;
use crate::util::ids::{AllocationId, FpgaId, IdGen, NodeId, UserId, VfpgaId, VmId};
use crate::util::json::Json;

/// What an allocation leases.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AllocKind {
    /// One vFPGA region (RAaaS / BAaaS).
    Vfpga(VfpgaId),
    /// A whole physical device (RSaaS).
    Physical(FpgaId),
    /// A VM with a physical device passed through (RSaaS extension).
    Vm(VmId, FpgaId),
}

/// One lease.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub id: AllocationId,
    pub user: UserId,
    pub kind: AllocKind,
    pub model: ServiceModel,
    /// Virtual timestamp of creation (for accounting).
    pub created_ns: u64,
}

/// One physical device row.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceEntry {
    pub id: FpgaId,
    pub node: NodeId,
    pub board: BoardKind,
    /// vFPGA regions carved on the device.
    pub regions: Vec<VfpgaId>,
    /// Service models this device is assigned to.
    pub models: Vec<ServiceModel>,
    /// Set when an RSaaS lease takes the whole device ("has to be
    /// marked separately in the device database and is therefore
    /// excluded from vFPGA allocations").
    pub exclusive_alloc: Option<AllocationId>,
}

/// The database.
#[derive(Debug, Default)]
pub struct DeviceDb {
    pub users: BTreeMap<UserId, String>,
    pub devices: BTreeMap<FpgaId, DeviceEntry>,
    pub allocations: BTreeMap<AllocationId, Allocation>,
    /// vFPGA → holding allocation (fast owner lookup).
    pub vfpga_owner: BTreeMap<VfpgaId, AllocationId>,
    pub alloc_ids: IdGen,
    pub user_ids: IdGen,
    pub vm_ids: IdGen,
}

impl DeviceDb {
    pub fn new() -> DeviceDb {
        DeviceDb::default()
    }

    // -------------------------------------------------------- users

    pub fn add_user(&mut self, name: &str) -> UserId {
        let id = UserId(self.user_ids.next());
        self.users.insert(id, name.to_string());
        id
    }

    pub fn user_name(&self, id: UserId) -> Option<&str> {
        self.users.get(&id).map(|s| s.as_str())
    }

    // ------------------------------------------------------ devices

    pub fn add_device(&mut self, entry: DeviceEntry) {
        self.devices.insert(entry.id, entry);
    }

    pub fn device(&self, id: FpgaId) -> Option<&DeviceEntry> {
        self.devices.get(&id)
    }

    /// Device hosting a given vFPGA region.
    pub fn device_of_vfpga(&self, v: VfpgaId) -> Option<&DeviceEntry> {
        self.devices.values().find(|d| d.regions.contains(&v))
    }

    // -------------------------------------------------- allocations

    /// Record a vFPGA lease.
    pub fn allocate_vfpga(
        &mut self,
        user: UserId,
        vfpga: VfpgaId,
        model: ServiceModel,
        now_ns: u64,
    ) -> Result<AllocationId, String> {
        if self.vfpga_owner.contains_key(&vfpga) {
            return Err(format!("{vfpga} already allocated"));
        }
        let dev = self
            .device_of_vfpga(vfpga)
            .ok_or_else(|| format!("{vfpga} not in database"))?;
        if dev.exclusive_alloc.is_some() {
            return Err(format!(
                "device {} exclusively allocated (RSaaS)",
                dev.id
            ));
        }
        let id = AllocationId(self.alloc_ids.next());
        self.allocations.insert(
            id,
            Allocation {
                id,
                user,
                kind: AllocKind::Vfpga(vfpga),
                model,
                created_ns: now_ns,
            },
        );
        self.vfpga_owner.insert(vfpga, id);
        Ok(id)
    }

    /// Record an exclusive physical lease (RSaaS), optionally inside
    /// a VM.
    pub fn allocate_physical(
        &mut self,
        user: UserId,
        fpga: FpgaId,
        vm: Option<VmId>,
        now_ns: u64,
    ) -> Result<AllocationId, String> {
        // Reject if any region of the device is currently leased.
        let dev = self
            .devices
            .get(&fpga)
            .ok_or_else(|| format!("{fpga} not in database"))?;
        if dev.exclusive_alloc.is_some() {
            return Err(format!("{fpga} already exclusively allocated"));
        }
        if let Some(v) = dev
            .regions
            .iter()
            .find(|v| self.vfpga_owner.contains_key(v))
        {
            return Err(format!("{fpga} has active vFPGA lease on {v}"));
        }
        let id = AllocationId(self.alloc_ids.next());
        let kind = match vm {
            Some(vm) => AllocKind::Vm(vm, fpga),
            None => AllocKind::Physical(fpga),
        };
        self.allocations.insert(
            id,
            Allocation {
                id,
                user,
                kind,
                model: ServiceModel::RSaaS,
                created_ns: now_ns,
            },
        );
        self.devices.get_mut(&fpga).unwrap().exclusive_alloc = Some(id);
        Ok(id)
    }

    /// Re-insert an allocation recovered from the scheduler journal,
    /// preserving its original [`AllocationId`] so lease tokens minted
    /// before the crash keep referring to the same allocation. The
    /// id generator is bumped past the adopted id so fresh
    /// allocations never collide with recovered ones.
    pub fn adopt_allocation(
        &mut self,
        id: AllocationId,
        user: UserId,
        kind: AllocKind,
        model: ServiceModel,
        now_ns: u64,
    ) -> Result<(), String> {
        if self.allocations.contains_key(&id) {
            return Err(format!("{id} already in database"));
        }
        match kind {
            AllocKind::Vfpga(v) => {
                if self.vfpga_owner.contains_key(&v) {
                    return Err(format!("{v} already allocated"));
                }
                let dev = self
                    .device_of_vfpga(v)
                    .ok_or_else(|| format!("{v} not in database"))?;
                if dev.exclusive_alloc.is_some() {
                    return Err(format!(
                        "device {} exclusively allocated (RSaaS)",
                        dev.id
                    ));
                }
                self.vfpga_owner.insert(v, id);
            }
            AllocKind::Physical(f) | AllocKind::Vm(_, f) => {
                let dev = self
                    .devices
                    .get(&f)
                    .ok_or_else(|| format!("{f} not in database"))?;
                if dev.exclusive_alloc.is_some() {
                    return Err(format!("{f} already exclusively allocated"));
                }
                if let Some(v) = dev
                    .regions
                    .iter()
                    .find(|v| self.vfpga_owner.contains_key(v))
                {
                    return Err(format!("{f} has active vFPGA lease on {v}"));
                }
                self.devices.get_mut(&f).unwrap().exclusive_alloc = Some(id);
            }
        }
        self.allocations.insert(
            id,
            Allocation {
                id,
                user,
                kind,
                model,
                created_ns: now_ns,
            },
        );
        self.alloc_ids.bump_past(id.0);
        Ok(())
    }

    /// Release any lease.
    pub fn release(&mut self, id: AllocationId) -> Result<Allocation, String> {
        let alloc = self
            .allocations
            .remove(&id)
            .ok_or_else(|| format!("{id} not found"))?;
        match &alloc.kind {
            AllocKind::Vfpga(v) => {
                self.vfpga_owner.remove(v);
            }
            AllocKind::Physical(f) | AllocKind::Vm(_, f) => {
                if let Some(dev) = self.devices.get_mut(f) {
                    dev.exclusive_alloc = None;
                }
            }
        }
        Ok(alloc)
    }

    pub fn allocation(&self, id: AllocationId) -> Option<&Allocation> {
        self.allocations.get(&id)
    }

    /// The allocation holding a vFPGA, if any.
    pub fn owner_of(&self, v: VfpgaId) -> Option<&Allocation> {
        self.vfpga_owner
            .get(&v)
            .and_then(|id| self.allocations.get(id))
    }

    /// All leases of one user.
    pub fn user_allocations(&self, user: UserId) -> Vec<&Allocation> {
        self.allocations
            .values()
            .filter(|a| a.user == user)
            .collect()
    }

    /// Free (unleased) regions of a device, in id order.
    pub fn free_regions(&self, fpga: FpgaId) -> Vec<VfpgaId> {
        self.devices
            .get(&fpga)
            .map(|d| {
                if d.exclusive_alloc.is_some() {
                    return Vec::new();
                }
                d.regions
                    .iter()
                    .filter(|v| !self.vfpga_owner.contains_key(v))
                    .copied()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Leased-region count of a device (placement input).
    pub fn used_regions(&self, fpga: FpgaId) -> usize {
        self.devices
            .get(&fpga)
            .map(|d| {
                d.regions
                    .iter()
                    .filter(|v| self.vfpga_owner.contains_key(v))
                    .count()
            })
            .unwrap_or(0)
    }

    // -------------------------------------------------- persistence

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "users",
                Json::Obj(
                    self.users
                        .iter()
                        .map(|(id, name)| {
                            (id.to_string(), Json::from(name.as_str()))
                        })
                        .collect(),
                ),
            ),
            (
                "devices",
                Json::Arr(
                    self.devices
                        .values()
                        .map(|d| {
                            Json::obj(vec![
                                ("id", Json::from(d.id.to_string())),
                                ("node", Json::from(d.node.to_string())),
                                ("board", Json::from(d.board.name())),
                                (
                                    "regions",
                                    Json::Arr(
                                        d.regions
                                            .iter()
                                            .map(|r| {
                                                Json::from(r.to_string())
                                            })
                                            .collect(),
                                    ),
                                ),
                                (
                                    "models",
                                    Json::Arr(
                                        d.models
                                            .iter()
                                            .map(|m| Json::from(m.name()))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "exclusive_alloc",
                                    match d.exclusive_alloc {
                                        Some(a) => {
                                            Json::from(a.to_string())
                                        }
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "allocations",
                Json::Arr(
                    self.allocations
                        .values()
                        .map(|a| {
                            let (kind, target) = match &a.kind {
                                AllocKind::Vfpga(v) => {
                                    ("vfpga", v.to_string())
                                }
                                AllocKind::Physical(f) => {
                                    ("physical", f.to_string())
                                }
                                AllocKind::Vm(vm, f) => {
                                    ("vm", format!("{vm}:{f}"))
                                }
                            };
                            Json::obj(vec![
                                ("id", Json::from(a.id.to_string())),
                                ("user", Json::from(a.user.to_string())),
                                ("kind", Json::from(kind)),
                                ("target", Json::from(target)),
                                ("model", Json::from(a.model.name())),
                                ("created_ns", Json::from(a.created_ns)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Restore from `to_json` output.
    pub fn from_json(v: &Json) -> Result<DeviceDb, String> {
        let mut db = DeviceDb::new();
        if let Some(users) = v.get("users").as_obj() {
            for (id, name) in users {
                let uid = UserId::parse(id).ok_or("bad user id")?;
                db.users.insert(
                    uid,
                    name.as_str().ok_or("bad user name")?.to_string(),
                );
                db.user_ids.bump_past(uid.0);
            }
        }
        for d in v.get("devices").as_arr().unwrap_or(&[]) {
            let id = FpgaId::parse(d.str_field("id")?).ok_or("bad fpga id")?;
            let node =
                NodeId::parse(d.str_field("node")?).ok_or("bad node id")?;
            let board = BoardKind::parse(d.str_field("board")?)
                .ok_or("bad board")?;
            let regions = d
                .get("regions")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|r| {
                    r.as_str()
                        .and_then(VfpgaId::parse)
                        .ok_or("bad region id".to_string())
                })
                .collect::<Result<Vec<_>, _>>()?;
            let models = d
                .get("models")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|m| m.as_str().and_then(ServiceModel::parse))
                .collect();
            let exclusive_alloc = d
                .get("exclusive_alloc")
                .as_str()
                .and_then(AllocationId::parse);
            db.add_device(DeviceEntry {
                id,
                node,
                board,
                regions,
                models,
                exclusive_alloc,
            });
        }
        for a in v.get("allocations").as_arr().unwrap_or(&[]) {
            let id = AllocationId::parse(a.str_field("id")?)
                .ok_or("bad alloc id")?;
            let user =
                UserId::parse(a.str_field("user")?).ok_or("bad user")?;
            let model = ServiceModel::parse(a.str_field("model")?)
                .ok_or("bad model")?;
            let target = a.str_field("target")?;
            let kind = match a.str_field("kind")? {
                "vfpga" => AllocKind::Vfpga(
                    VfpgaId::parse(target).ok_or("bad vfpga")?,
                ),
                "physical" => AllocKind::Physical(
                    FpgaId::parse(target).ok_or("bad fpga")?,
                ),
                "vm" => {
                    let (vm, f) =
                        target.split_once(':').ok_or("bad vm target")?;
                    AllocKind::Vm(
                        VmId::parse(vm).ok_or("bad vm id")?,
                        FpgaId::parse(f).ok_or("bad fpga id")?,
                    )
                }
                k => return Err(format!("bad alloc kind {k}")),
            };
            if let AllocKind::Vfpga(v) = &kind {
                db.vfpga_owner.insert(*v, id);
            }
            db.allocations.insert(
                id,
                Allocation {
                    id,
                    user,
                    kind,
                    model,
                    created_ns: a.get("created_ns").as_u64().unwrap_or(0),
                },
            );
            db.alloc_ids.bump_past(id.0);
        }
        Ok(db)
    }

    /// Durably save the database (temp file + fsync + atomic rename,
    /// so a crash mid-save can never leave a torn file).
    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        crate::util::fsx::write_atomic(path, &self.to_json().to_pretty())
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<DeviceDb, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        DeviceDb::from_json(
            &Json::parse(&text).map_err(|e| e.to_string())?,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db_with_two_devices() -> DeviceDb {
        let mut db = DeviceDb::new();
        db.add_device(DeviceEntry {
            id: FpgaId(0),
            node: NodeId(0),
            board: BoardKind::Vc707,
            regions: (0..4).map(VfpgaId).collect(),
            models: vec![ServiceModel::RAaaS, ServiceModel::BAaaS],
            exclusive_alloc: None,
        });
        db.add_device(DeviceEntry {
            id: FpgaId(1),
            node: NodeId(0),
            board: BoardKind::Vc707,
            regions: (4..8).map(VfpgaId).collect(),
            models: vec![ServiceModel::RSaaS, ServiceModel::RAaaS],
            exclusive_alloc: None,
        });
        db
    }

    #[test]
    fn vfpga_lease_lifecycle() {
        let mut db = db_with_two_devices();
        let u = db.add_user("alice");
        let a = db
            .allocate_vfpga(u, VfpgaId(0), ServiceModel::RAaaS, 1)
            .unwrap();
        assert_eq!(db.owner_of(VfpgaId(0)).unwrap().user, u);
        assert_eq!(db.free_regions(FpgaId(0)).len(), 3);
        assert_eq!(db.used_regions(FpgaId(0)), 1);
        // Double allocation rejected.
        assert!(db
            .allocate_vfpga(u, VfpgaId(0), ServiceModel::RAaaS, 2)
            .is_err());
        db.release(a).unwrap();
        assert!(db.owner_of(VfpgaId(0)).is_none());
        assert_eq!(db.free_regions(FpgaId(0)).len(), 4);
    }

    #[test]
    fn adopt_preserves_id_and_bumps_generator() {
        let mut db = db_with_two_devices();
        let u = db.add_user("alice");
        db.adopt_allocation(
            AllocationId(7),
            u,
            AllocKind::Vfpga(VfpgaId(2)),
            ServiceModel::RAaaS,
            10,
        )
        .unwrap();
        assert_eq!(db.owner_of(VfpgaId(2)).unwrap().id, AllocationId(7));
        // Duplicate id and already-owned region both rejected.
        assert!(db
            .adopt_allocation(
                AllocationId(7),
                u,
                AllocKind::Vfpga(VfpgaId(3)),
                ServiceModel::RAaaS,
                10,
            )
            .is_err());
        assert!(db
            .adopt_allocation(
                AllocationId(8),
                u,
                AllocKind::Vfpga(VfpgaId(2)),
                ServiceModel::RAaaS,
                10,
            )
            .is_err());
        // Fresh ids mint past the adopted one.
        let fresh = db
            .allocate_vfpga(u, VfpgaId(0), ServiceModel::RAaaS, 11)
            .unwrap();
        assert!(fresh.0 > 7, "fresh {fresh:?} must not collide");
        // Exclusive adoption marks the device.
        db.adopt_allocation(
            AllocationId(20),
            u,
            AllocKind::Physical(FpgaId(1)),
            ServiceModel::RSaaS,
            12,
        )
        .unwrap();
        assert!(db.free_regions(FpgaId(1)).is_empty());
    }

    #[test]
    fn rsaas_excludes_vfpga_allocation() {
        let mut db = db_with_two_devices();
        let u = db.add_user("bob");
        let a = db.allocate_physical(u, FpgaId(1), None, 0).unwrap();
        // Regions of an exclusively-held device are not allocatable.
        assert!(db
            .allocate_vfpga(u, VfpgaId(4), ServiceModel::RAaaS, 0)
            .is_err());
        assert!(db.free_regions(FpgaId(1)).is_empty());
        // And vice versa: active vFPGA lease blocks exclusive.
        db.release(a).unwrap();
        db.allocate_vfpga(u, VfpgaId(4), ServiceModel::RAaaS, 0)
            .unwrap();
        assert!(db.allocate_physical(u, FpgaId(1), None, 0).is_err());
    }

    #[test]
    fn vm_allocation_is_exclusive() {
        let mut db = db_with_two_devices();
        let u = db.add_user("carol");
        let vm = VmId(db.vm_ids.next());
        db.allocate_physical(u, FpgaId(0), Some(vm), 0).unwrap();
        assert!(db.allocate_physical(u, FpgaId(0), None, 0).is_err());
        let dev = db.device(FpgaId(0)).unwrap();
        assert!(dev.exclusive_alloc.is_some());
    }

    #[test]
    fn unknown_ids_are_errors() {
        let mut db = db_with_two_devices();
        let u = db.add_user("dave");
        assert!(db
            .allocate_vfpga(u, VfpgaId(99), ServiceModel::RAaaS, 0)
            .is_err());
        assert!(db.allocate_physical(u, FpgaId(9), None, 0).is_err());
        assert!(db.release(AllocationId(404)).is_err());
    }

    #[test]
    fn user_allocations_filter() {
        let mut db = db_with_two_devices();
        let alice = db.add_user("alice");
        let bob = db.add_user("bob");
        db.allocate_vfpga(alice, VfpgaId(0), ServiceModel::RAaaS, 0)
            .unwrap();
        db.allocate_vfpga(bob, VfpgaId(1), ServiceModel::RAaaS, 0)
            .unwrap();
        db.allocate_vfpga(alice, VfpgaId(2), ServiceModel::BAaaS, 0)
            .unwrap();
        assert_eq!(db.user_allocations(alice).len(), 2);
        assert_eq!(db.user_allocations(bob).len(), 1);
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut db = db_with_two_devices();
        let u = db.add_user("alice");
        db.allocate_vfpga(u, VfpgaId(2), ServiceModel::BAaaS, 42)
            .unwrap();
        let vm = VmId(db.vm_ids.next());
        db.allocate_physical(u, FpgaId(1), Some(vm), 43).unwrap();
        let j = db.to_json();
        let back = DeviceDb::from_json(&j).unwrap();
        assert_eq!(back.to_json(), j);
        assert_eq!(back.owner_of(VfpgaId(2)).unwrap().user, u);
        assert_eq!(back.used_regions(FpgaId(0)), 1);
        // Id generators resume past reloaded ids.
        let next = AllocationId(back.alloc_ids.next());
        assert!(next.0 >= 2);
    }

    #[test]
    fn save_load_file() {
        let mut db = db_with_two_devices();
        let u = db.add_user("eve");
        db.allocate_vfpga(u, VfpgaId(3), ServiceModel::RAaaS, 7)
            .unwrap();
        let path = std::env::temp_dir()
            .join(format!("rc3e_db_{}.json", std::process::id()));
        db.save(&path).unwrap();
        let back = DeviceDb::load(&path).unwrap();
        assert_eq!(back.to_json(), db.to_json());
        std::fs::remove_file(&path).unwrap();
    }
}
