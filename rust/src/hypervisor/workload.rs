//! Cloud workload generator + session simulator.
//!
//! The paper's testbed is interactive (a handful of users on 2 nodes);
//! to evaluate the *resource manager* beyond hand workloads we drive
//! it with a synthetic multi-user session mix: Poisson arrivals, each
//! session leasing a vFPGA, programming a core, holding the lease for
//! an exponential service time (charged to the virtual clock) and
//! releasing. The generator measures what a cloud operator cares
//! about: admission rate, allocation latency, achieved utilization
//! and energy — and is the substrate for `bench ablation_placement`'s
//! dynamic variant and the monitor's long-run tests.

use std::sync::Arc;

use super::core::{Hypervisor, HypervisorError};
use super::monitor::Monitor;
use crate::config::ServiceModel;
use crate::util::clock::VirtualTime;
use crate::util::rng::Rng;

/// Workload description.
#[derive(Debug, Clone)]
pub struct CloudWorkload {
    /// Session arrival rate (sessions/sec of virtual time).
    pub arrival_rate: f64,
    /// Mean lease hold time in seconds (exponential).
    pub mean_hold_s: f64,
    /// Total sessions to generate.
    pub sessions: usize,
    /// Seed for the whole run.
    pub seed: u64,
}

impl CloudWorkload {
    /// A light load the paper-scale testbed can absorb.
    pub fn light() -> CloudWorkload {
        CloudWorkload {
            arrival_rate: 0.05,
            mean_hold_s: 120.0,
            sessions: 40,
            seed: 0x10AD,
        }
    }

    /// Overload: arrivals outpace capacity, rejections expected.
    pub fn heavy() -> CloudWorkload {
        CloudWorkload {
            arrival_rate: 0.5,
            mean_hold_s: 240.0,
            sessions: 80,
            seed: 0x4EA7,
        }
    }
}

/// Per-session result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionOutcome {
    /// Admitted; held and released normally.
    Served,
    /// No capacity at arrival time.
    Rejected,
}

/// Aggregate report.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub served: usize,
    pub rejected: usize,
    /// Mean PR-to-ready latency (virtual ms) across served sessions.
    pub mean_setup_ms: f64,
    /// Mean configured-region utilization sampled at each arrival.
    pub mean_utilization: f64,
    /// Total virtual makespan.
    pub makespan: VirtualTime,
    /// Total energy over the run (J).
    pub energy_j: f64,
}

impl WorkloadReport {
    pub fn admission_rate(&self) -> f64 {
        let total = self.served + self.rejected;
        if total == 0 {
            0.0
        } else {
            self.served as f64 / total as f64
        }
    }
}

/// Event-driven execution: sessions arrive by Poisson process; ends
/// are processed in virtual-time order between arrivals.
pub fn run(
    hv: &Hypervisor,
    w: &CloudWorkload,
) -> Result<WorkloadReport, HypervisorError> {
    let mut rng = Rng::new(w.seed);
    let mut monitor = Monitor::new();
    let clock = Arc::clone(&hv.clock);
    let t_start = clock.now();
    // (end_time, alloc) of live sessions, kept sorted by end_time.
    let mut live: Vec<(VirtualTime, crate::util::ids::AllocationId)> =
        Vec::new();
    let mut served = 0usize;
    let mut rejected = 0usize;
    let mut setup_ms_sum = 0.0;
    let mut util_sum = 0.0;
    let user = hv.add_user("workload");

    let mut now = clock.now();
    for _ in 0..w.sessions {
        // Advance to the next arrival, releasing sessions that end
        // before it.
        let gap = VirtualTime::from_secs_f64(rng.next_exp(w.arrival_rate));
        let arrival = now + gap;
        live.sort_by_key(|(end, _)| *end);
        while let Some(&(end, alloc)) = live.first() {
            if end > arrival {
                break;
            }
            // Move the clock to the session end, then release.
            let behind = end.saturating_sub(clock.now());
            clock.advance(behind);
            hv.release(alloc)?;
            live.remove(0);
        }
        let behind = arrival.saturating_sub(clock.now());
        clock.advance(behind);
        now = clock.now();

        // Sample utilization at each arrival (monitor path).
        monitor.sample_all(hv);
        util_sum += monitor.cloud_utilization();

        // Try to admit.
        match hv.alloc_vfpga(user, ServiceModel::RAaaS) {
            Err(HypervisorError::NoCapacity) => {
                rejected += 1;
            }
            Err(e) => return Err(e),
            Ok((alloc, vfpga, fpga, _)) => {
                // Program a small core (PR latency = setup).
                let t0 = clock.now();
                let dev = hv.device(fpga)?;
                let slot = dev.slot_of[&vfpga];
                let part = dev.fpga.lock().unwrap().board.part;
                let bs = crate::bitstream::BitstreamBuilder::partial(
                    part, "session",
                )
                .resources(crate::fpga::Resources::new(100, 100, 1, 1))
                .frames(crate::hls::flow::region_window(slot, 1))
                .payload_seed(rng.next_u64())
                .build();
                hv.program_vfpga(alloc, user, &bs)?;
                setup_ms_sum += clock.since(t0).as_millis_f64();
                served += 1;
                let hold =
                    VirtualTime::from_secs_f64(rng.next_exp(1.0 / w.mean_hold_s));
                live.push((clock.now() + hold, alloc));
            }
        }
    }
    // Drain the tail.
    live.sort_by_key(|(end, _)| *end);
    for (end, alloc) in live {
        let behind = end.saturating_sub(clock.now());
        clock.advance(behind);
        hv.release(alloc)?;
    }
    Ok(WorkloadReport {
        served,
        rejected,
        mean_setup_ms: if served > 0 {
            setup_ms_sum / served as f64
        } else {
            0.0
        },
        mean_utilization: util_sum / w.sessions.max(1) as f64,
        makespan: clock.since(t_start),
        energy_j: hv.total_energy_joules(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypervisor::PlacementPolicy;
    use crate::util::clock::VirtualClock;

    fn hv(policy: PlacementPolicy) -> Hypervisor {
        Hypervisor::boot(
            &crate::config::ClusterConfig::paper_testbed(),
            VirtualClock::new(),
            policy,
        )
        .unwrap()
    }

    #[test]
    fn light_load_is_fully_admitted() {
        let hv = hv(PlacementPolicy::ConsolidateFirst);
        let report = run(&hv, &CloudWorkload::light()).unwrap();
        assert_eq!(report.served, 40);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.admission_rate(), 1.0);
        // PR + orchestration per admission: 843 ms on VC707, 460 ms
        // on ML605 (PR scales with the config image) — the mean sits
        // between.
        assert!(
            report.mean_setup_ms > 440.0 && report.mean_setup_ms < 850.0,
            "mean setup {} ms",
            report.mean_setup_ms
        );
    }

    #[test]
    fn heavy_load_rejects_but_never_corrupts() {
        let hv = hv(PlacementPolicy::ConsolidateFirst);
        let w = CloudWorkload {
            arrival_rate: 0.5,
            mean_hold_s: 240.0,
            sessions: 80,
            seed: 0xBEEF,
        };
        let report = run(&hv, &w).unwrap();
        assert!(report.rejected > 0, "heavy load should reject");
        assert!(report.admission_rate() > 0.2);
        // Everything released at the end.
        let db = hv.db.lock().unwrap();
        let used: usize = hv
            .device_ids()
            .iter()
            .map(|f| db.used_regions(*f))
            .sum();
        assert_eq!(used, 0);
    }

    #[test]
    fn heavier_load_has_higher_utilization() {
        let light = run(
            &hv(PlacementPolicy::ConsolidateFirst),
            &CloudWorkload::light(),
        )
        .unwrap();
        let heavy = run(
            &hv(PlacementPolicy::ConsolidateFirst),
            &CloudWorkload {
                arrival_rate: 0.5,
                mean_hold_s: 240.0,
                sessions: 80,
                seed: 0x10AD,
            },
        )
        .unwrap();
        assert!(heavy.mean_utilization > light.mean_utilization);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run(
            &hv(PlacementPolicy::ConsolidateFirst),
            &CloudWorkload::light(),
        )
        .unwrap();
        let b = run(
            &hv(PlacementPolicy::ConsolidateFirst),
            &CloudWorkload::light(),
        )
        .unwrap();
        assert_eq!(a.served, b.served);
        assert_eq!(a.rejected, b.rejected);
        assert_eq!(a.makespan, b.makespan);
    }

    #[test]
    fn consolidation_beats_spread_on_energy_under_load() {
        let w = CloudWorkload::light();
        let cons = run(&hv(PlacementPolicy::ConsolidateFirst), &w).unwrap();
        let rr = run(&hv(PlacementPolicy::RoundRobin), &w).unwrap();
        // Same admissions either way at light load...
        assert_eq!(cons.served, rr.served);
        // ...but consolidation burns less energy.
        assert!(
            cons.energy_j < rr.energy_j,
            "consolidate {:.0} J !< roundrobin {:.0} J",
            cons.energy_j,
            rr.energy_j
        );
    }
}
