//! RC3E — the FPGA cloud hypervisor (the paper's core contribution).
//!
//! "In our approach the hypervisor allows users to implement and
//! execute their own hardware designs on virtual FPGAs... the RC3E
//! hypervisor acts as a resource manager with load distribution. The
//! hypervisor has access to a database containing all physical and
//! virtual FPGA devices in the cloud system and their allocation
//! status." (Section IV-B)
//!
//! Submodules:
//! * [`db`] — the device database (users, devices, allocations) with
//!   JSON persistence;
//! * [`placement`] — vFPGA placement policies (consolidate-first is
//!   the paper's energy rule; round-robin is the ablation baseline);
//! * [`core`] — the [`core::Hypervisor`] itself: boot, allocation for
//!   the three service models, PR orchestration with sanity checking,
//!   status calls, energy accounting;
//! * [`migration`] — design migration between vFPGAs / devices (the
//!   paper's future-work feature, implemented) — quiesce-based: a
//!   relocation first wins a region quiesce ([`guard`]), so it can
//!   never race an in-flight setup;
//! * [`guard`] — the pin/quiesce layer backing that guarantee.

pub mod core;
pub mod db;
pub mod guard;
pub mod migration;
pub mod monitor;
pub mod placement;
pub mod workload;

pub use self::core::{Hypervisor, HypervisorError, ManagedDevice};
pub use db::{AllocKind, Allocation, DeviceDb, DeviceEntry};
pub use guard::{PinGuard, QuiesceGuard, RegionGuards};
pub use monitor::{DeviceSummary, Monitor};
pub use placement::{Candidate, PlacementPolicy};
pub use workload::{CloudWorkload, SessionOutcome, WorkloadReport};

/// Modeled RC3E orchestration overheads beyond the raw RPC hop,
/// calibrated against Table I (over-RC3E minus local minus RPC).
pub mod overhead {
    /// Device-file open + driver round-trip for a local status call
    /// (Table I local row is ~11 ms; the gcs access itself is
    /// 0.198 ms, the rest is driver/devfile overhead).
    pub const STATUS_DEVFILE_MS: f64 = 10.8;
    /// Extra orchestration for a full configuration via RC3E:
    /// link-param snapshot, PCIe hot-plug rescan after the endpoint
    /// returns, database update. Table I: 29.513 − 28.370 − 0.069 s.
    pub const FULL_CONFIG_ORCH_MS: f64 = 1_074.0;
    /// Extra orchestration for PR via RC3E: bitfile sanity check,
    /// controller + database update. Table I: 912 − 732 − 69 ms.
    pub const PR_ORCH_MS: f64 = 111.0;
}
