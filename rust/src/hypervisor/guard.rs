//! Pin/quiesce guards over vFPGA regions.
//!
//! The lifecycle state machine ([`crate::fpga::lifecycle`]) makes
//! illegal region *states* unrepresentable; this module makes illegal
//! region *interleavings* unrepresentable. Two kinds of guard exist
//! per region:
//!
//! * a **pin** ([`PinGuard`]) — held by in-flight setup and streaming
//!   (retarget + PR orchestration, session streaming). Any number of
//!   pins may coexist; a pin blocks while the region is quiesced.
//! * a **quiesce** ([`QuiesceGuard`]) — exclusive: it is granted only
//!   when no pin is held and no other quiesce is active. Relocation
//!   (migration, preemption) and teardown (release) must win a
//!   quiesce before touching any region state.
//!
//! Because a quiesce excludes pins, a relocation can never observe a
//! region mid-`Programming`: the race the old `with_preemption_retry`
//! absorbed is deleted structurally, not retried around. Preemption
//! uses [`RegionGuards::try_quiesce`] so a pinned (busy) victim is
//! *skipped*, never raced; the explicit `migrate` RPC and release use
//! [`RegionGuards::quiesce_blocking`] and wait for pins to drain.
//!
//! Waiting is wall-clock only (the virtual clock never advances while
//! parked); the hypervisor records the measured wait in the
//! `sched.preempt.quiesce_wait` histogram.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::ids::VfpgaId;

#[derive(Debug, Clone, Copy, Default)]
struct GuardState {
    pins: u32,
    quiesced: bool,
}

impl GuardState {
    fn is_default(self) -> bool {
        self.pins == 0 && !self.quiesced
    }
}

/// The per-cluster guard table (region ids are cluster-unique).
#[derive(Debug, Default)]
pub struct RegionGuards {
    state: Mutex<BTreeMap<VfpgaId, GuardState>>,
    changed: Condvar,
}

impl RegionGuards {
    pub fn new() -> Arc<RegionGuards> {
        Arc::new(RegionGuards::default())
    }

    /// Take a pin on `region`, waiting out any active quiesce.
    pub fn pin(self: &Arc<Self>, region: VfpgaId) -> PinGuard {
        let mut st = self.state.lock().unwrap();
        loop {
            {
                let entry = st.entry(region).or_default();
                if !entry.quiesced {
                    entry.pins += 1;
                    return PinGuard {
                        guards: Arc::clone(self),
                        region,
                    };
                }
            }
            st = self.changed.wait(st).unwrap();
        }
    }

    /// Win a quiesce on `region` only if it is immediately winnable
    /// (no pins, no other quiesce). Never blocks — the preemption
    /// path's "only quiescable victims" rule.
    pub fn try_quiesce(
        self: &Arc<Self>,
        region: VfpgaId,
    ) -> Option<QuiesceGuard> {
        let mut st = self.state.lock().unwrap();
        let entry = st.entry(region).or_default();
        if entry.is_default() {
            entry.quiesced = true;
            Some(QuiesceGuard {
                guards: Arc::clone(self),
                region,
            })
        } else {
            None
        }
    }

    /// Win a quiesce on `region`, waiting for pins to drain. Returns
    /// the guard and the wall time spent waiting.
    pub fn quiesce_blocking(
        self: &Arc<Self>,
        region: VfpgaId,
    ) -> (QuiesceGuard, Duration) {
        let t0 = Instant::now();
        let mut st = self.state.lock().unwrap();
        loop {
            {
                let entry = st.entry(region).or_default();
                if entry.is_default() {
                    entry.quiesced = true;
                    return (
                        QuiesceGuard {
                            guards: Arc::clone(self),
                            region,
                        },
                        t0.elapsed(),
                    );
                }
            }
            st = self.changed.wait(st).unwrap();
        }
    }

    /// Would a `try_quiesce` succeed right now? (Advisory: the answer
    /// can go stale; callers still take the real guard.)
    pub fn is_quiescable(&self, region: VfpgaId) -> bool {
        let st = self.state.lock().unwrap();
        st.get(&region).map_or(true, |e| e.is_default())
    }

    /// Live pins on a region (tests, telemetry).
    pub fn pins(&self, region: VfpgaId) -> u32 {
        let st = self.state.lock().unwrap();
        st.get(&region).map_or(0, |e| e.pins)
    }

    fn unpin(&self, region: VfpgaId) {
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.get_mut(&region) {
            e.pins = e.pins.saturating_sub(1);
            if e.is_default() {
                st.remove(&region);
            }
        }
        drop(st);
        self.changed.notify_all();
    }

    fn unquiesce(&self, region: VfpgaId) {
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.get_mut(&region) {
            e.quiesced = false;
            if e.is_default() {
                st.remove(&region);
            }
        }
        drop(st);
        self.changed.notify_all();
    }
}

/// A held pin; dropping it releases the region to quiescers.
#[derive(Debug)]
pub struct PinGuard {
    guards: Arc<RegionGuards>,
    region: VfpgaId,
}

impl PinGuard {
    pub fn region(&self) -> VfpgaId {
        self.region
    }
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        self.guards.unpin(self.region);
    }
}

/// A won quiesce; dropping it re-admits pinners.
#[derive(Debug)]
pub struct QuiesceGuard {
    guards: Arc<RegionGuards>,
    region: VfpgaId,
}

impl QuiesceGuard {
    pub fn region(&self) -> VfpgaId {
        self.region
    }
}

impl Drop for QuiesceGuard {
    fn drop(&mut self) {
        self.guards.unquiesce(self.region);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pins_block_quiesce_until_dropped() {
        let g = RegionGuards::new();
        let r = VfpgaId(1);
        let pin = g.pin(r);
        assert!(!g.is_quiescable(r));
        assert!(g.try_quiesce(r).is_none());
        drop(pin);
        assert!(g.is_quiescable(r));
        let q = g.try_quiesce(r).expect("no pins left");
        assert_eq!(q.region(), r);
        // Second quiesce loses.
        assert!(g.try_quiesce(r).is_none());
        drop(q);
        assert!(g.try_quiesce(r).is_some());
    }

    #[test]
    fn pins_are_counted_and_nest() {
        let g = RegionGuards::new();
        let r = VfpgaId(2);
        let a = g.pin(r);
        let b = g.pin(r);
        assert_eq!(g.pins(r), 2);
        drop(a);
        assert!(g.try_quiesce(r).is_none(), "one pin still held");
        drop(b);
        assert_eq!(g.pins(r), 0);
        assert!(g.try_quiesce(r).is_some());
    }

    #[test]
    fn regions_are_independent() {
        let g = RegionGuards::new();
        let _pin = g.pin(VfpgaId(3));
        assert!(g.try_quiesce(VfpgaId(4)).is_some());
    }

    #[test]
    fn quiesce_blocking_waits_for_pin_drain() {
        let g = RegionGuards::new();
        let r = VfpgaId(5);
        let pin = g.pin(r);
        let g2 = Arc::clone(&g);
        let waiter = std::thread::spawn(move || {
            let (guard, waited) = g2.quiesce_blocking(r);
            drop(guard);
            waited
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(pin);
        let waited = waiter.join().unwrap();
        assert!(waited >= Duration::from_millis(10), "{waited:?}");
    }

    #[test]
    fn pin_waits_out_a_quiesce() {
        let g = RegionGuards::new();
        let r = VfpgaId(6);
        let q = g.try_quiesce(r).unwrap();
        let g2 = Arc::clone(&g);
        let pinner = std::thread::spawn(move || g2.pin(r));
        std::thread::sleep(Duration::from_millis(20));
        drop(q);
        let pin = pinner.join().unwrap();
        assert_eq!(pin.region(), r);
        assert_eq!(g.pins(r), 1, "pin released on guard drop only");
        drop(pin);
        assert_eq!(g.pins(r), 0);
    }

    #[test]
    fn threaded_pin_churn_never_leaks_state() {
        let g = RegionGuards::new();
        let r = VfpgaId(7);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let g = Arc::clone(&g);
                scope.spawn(move || {
                    for _ in 0..200 {
                        let _pin = g.pin(r);
                    }
                });
            }
        });
        assert_eq!(g.pins(r), 0);
        assert!(g.is_quiescable(r));
    }
}
