//! vFPGA placement policies.
//!
//! Section IV-B: "The resource manager always tries to minimize the
//! number of active vFPGAs and to maximize the utilization of
//! physical FPGAs to thereby reduce energy consumption." That is
//! consolidate-first (bin-packing) placement; round-robin (spread) is
//! implemented as the ablation baseline — `bench ablation_placement`
//! shows the energy difference, and also the throughput flip side:
//! spreading gives each core more PCIe bandwidth.

use crate::util::ids::{FpgaId, VfpgaId};

/// A device the allocator may place into.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub fpga: FpgaId,
    /// Regions currently leased on the device.
    pub used: usize,
    /// Free regions, in preference order.
    pub free: Vec<VfpgaId>,
}

/// Placement strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Pack onto the most-utilized device that still has room — the
    /// paper's energy-minimizing rule.
    ConsolidateFirst,
    /// Spread across least-utilized devices (bandwidth-friendly
    /// ablation baseline).
    RoundRobin,
}

impl PlacementPolicy {
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s {
            "consolidate" => Some(PlacementPolicy::ConsolidateFirst),
            "roundrobin" => Some(PlacementPolicy::RoundRobin),
            _ => None,
        }
    }

    /// Choose a device + region among candidates. Ties break on the
    /// lower device id (determinism). Candidates with no free region
    /// are skipped.
    pub fn choose(
        self,
        candidates: &[Candidate],
    ) -> Option<(FpgaId, VfpgaId)> {
        let viable = candidates.iter().filter(|c| !c.free.is_empty());
        let best = match self {
            PlacementPolicy::ConsolidateFirst => viable.min_by_key(|c| {
                (std::cmp::Reverse(c.used), c.fpga.0)
            }),
            PlacementPolicy::RoundRobin => {
                viable.min_by_key(|c| (c.used, c.fpga.0))
            }
        }?;
        Some((best.fpga, best.free[0]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn candidates() -> Vec<Candidate> {
        vec![
            Candidate {
                fpga: FpgaId(0),
                used: 1,
                free: vec![VfpgaId(1), VfpgaId(2), VfpgaId(3)],
            },
            Candidate {
                fpga: FpgaId(1),
                used: 3,
                free: vec![VfpgaId(7)],
            },
            Candidate {
                fpga: FpgaId(2),
                used: 0,
                free: (8..12).map(VfpgaId).collect(),
            },
        ]
    }

    #[test]
    fn consolidate_picks_fullest_with_room() {
        let (fpga, v) =
            PlacementPolicy::ConsolidateFirst.choose(&candidates()).unwrap();
        assert_eq!(fpga, FpgaId(1));
        assert_eq!(v, VfpgaId(7));
    }

    #[test]
    fn round_robin_picks_emptiest() {
        let (fpga, v) =
            PlacementPolicy::RoundRobin.choose(&candidates()).unwrap();
        assert_eq!(fpga, FpgaId(2));
        assert_eq!(v, VfpgaId(8));
    }

    #[test]
    fn full_devices_skipped() {
        let cands = vec![
            Candidate {
                fpga: FpgaId(0),
                used: 4,
                free: vec![],
            },
            Candidate {
                fpga: FpgaId(1),
                used: 2,
                free: vec![VfpgaId(5)],
            },
        ];
        for p in [
            PlacementPolicy::ConsolidateFirst,
            PlacementPolicy::RoundRobin,
        ] {
            assert_eq!(p.choose(&cands), Some((FpgaId(1), VfpgaId(5))));
        }
    }

    #[test]
    fn no_capacity_returns_none() {
        let cands = vec![Candidate {
            fpga: FpgaId(0),
            used: 4,
            free: vec![],
        }];
        assert_eq!(PlacementPolicy::ConsolidateFirst.choose(&cands), None);
        assert_eq!(PlacementPolicy::RoundRobin.choose(&cands), None);
    }

    #[test]
    fn deterministic_tie_break() {
        let cands = vec![
            Candidate {
                fpga: FpgaId(3),
                used: 1,
                free: vec![VfpgaId(13)],
            },
            Candidate {
                fpga: FpgaId(1),
                used: 1,
                free: vec![VfpgaId(5)],
            },
        ];
        assert_eq!(
            PlacementPolicy::ConsolidateFirst.choose(&cands),
            Some((FpgaId(1), VfpgaId(5)))
        );
        assert_eq!(
            PlacementPolicy::RoundRobin.choose(&cands),
            Some((FpgaId(1), VfpgaId(5)))
        );
    }

    #[test]
    fn parse_names() {
        assert_eq!(
            PlacementPolicy::parse("consolidate"),
            Some(PlacementPolicy::ConsolidateFirst)
        );
        assert_eq!(
            PlacementPolicy::parse("roundrobin"),
            Some(PlacementPolicy::RoundRobin)
        );
        assert_eq!(PlacementPolicy::parse("bestfit"), None);
    }

    #[test]
    fn consolidation_sequence_fills_one_device_first() {
        // Simulate 8 sequential placements over two empty devices.
        let mut used = [0usize, 0];
        let mut placements = Vec::new();
        for _ in 0..8 {
            let cands: Vec<Candidate> = (0..2)
                .map(|i| Candidate {
                    fpga: FpgaId(i as u64),
                    used: used[i],
                    free: (0..(4 - used[i]))
                        .map(|k| VfpgaId((i * 4 + used[i] + k) as u64))
                        .collect(),
                })
                .collect();
            let (f, _) = PlacementPolicy::ConsolidateFirst
                .choose(&cands)
                .unwrap();
            used[f.0 as usize] += 1;
            placements.push(f.0);
        }
        // First four land on device 0, next four on device 1.
        assert_eq!(placements, vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }
}
