//! Bitfile sanity checking — the paper's headline future-work item.
//!
//! Section VI: "we plan to implement sanity checking for (partial)
//! bitfiles to avoid both damage by a tampered bitstream and access to
//! the parts not reconfigurable by the users as for example physical
//! ports."
//!
//! Checks, in order:
//! 1. payload CRC (bit-rot / truncation),
//! 2. target part matches the device,
//! 3. kind matches the operation (full vs partial),
//! 4. claimed frame window inside the region's allowed window
//!    (the "tampered bitstream addressing foreign frames" attack),
//! 5. resource footprint fits the region envelope,
//! 6. optional provider-signature verification (policy-dependent —
//!    BAaaS bitfiles must be signed by the provider; RSaaS research
//!    systems may allow unsigned).

use super::{Bitstream, BitstreamKind, FrameRange};
use crate::fpga::resources::Resources;

/// What a deployment requires of incoming bitfiles.
#[derive(Debug, Clone)]
pub struct SanityPolicy {
    /// Require a valid provider signature.
    pub require_signature: bool,
    /// Provider key used to verify signatures.
    pub provider_key: String,
    /// Reject bitstreams whose claimed frames exceed this fraction of
    /// the window even if contained (defense in depth against
    /// over-broad claims).
    pub max_window_fill: f64,
}

impl SanityPolicy {
    /// Research/education deployment: signatures optional.
    pub fn research() -> SanityPolicy {
        SanityPolicy {
            require_signature: false,
            provider_key: "rc3e-provider".to_string(),
            max_window_fill: 1.0,
        }
    }

    /// Production BAaaS deployment: provider-signed bitfiles only.
    pub fn production() -> SanityPolicy {
        SanityPolicy {
            require_signature: true,
            provider_key: "rc3e-provider".to_string(),
            max_window_fill: 1.0,
        }
    }
}

/// Why a bitstream was rejected.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SanityError {
    #[error("payload CRC mismatch (corrupted or truncated bitfile)")]
    BadCrc,
    #[error("bitstream targets part '{0}', device is '{1}'")]
    WrongPart(String, String),
    #[error("expected a {expected:?} bitstream, got {got:?}")]
    WrongKind {
        expected: BitstreamKind,
        got: BitstreamKind,
    },
    #[error(
        "frames [{claim_start},{claim_end}) escape region window \
         [{win_start},{win_end}) — tampered bitstream?"
    )]
    FrameEscape {
        claim_start: u64,
        claim_end: u64,
        win_start: u64,
        win_end: u64,
    },
    #[error("design needs {needed} but region offers {offered}")]
    TooLarge { needed: String, offered: String },
    #[error("bitfile is unsigned but policy requires a provider signature")]
    Unsigned,
    #[error("provider signature verification failed")]
    BadSignature,
    #[error("empty frame window claimed")]
    EmptyFrames,
}

/// Stateless checker configured with a policy.
#[derive(Debug, Clone)]
pub struct SanityChecker {
    policy: SanityPolicy,
}

impl SanityChecker {
    pub fn new(policy: SanityPolicy) -> SanityChecker {
        SanityChecker { policy }
    }

    /// Validate a *partial* bitstream against a region's constraints.
    pub fn check_partial(
        &self,
        bs: &Bitstream,
        device_part: &str,
        region_window: FrameRange,
        region_capacity: Resources,
    ) -> Result<(), SanityError> {
        self.check_common(bs, device_part)?;
        if bs.kind != BitstreamKind::Partial {
            return Err(SanityError::WrongKind {
                expected: BitstreamKind::Partial,
                got: bs.kind,
            });
        }
        if bs.meta.frames.is_empty() {
            return Err(SanityError::EmptyFrames);
        }
        if !region_window.contains(bs.meta.frames) {
            return Err(SanityError::FrameEscape {
                claim_start: bs.meta.frames.start,
                claim_end: bs.meta.frames.end,
                win_start: region_window.start,
                win_end: region_window.end,
            });
        }
        let fill =
            bs.meta.frames.len() as f64 / region_window.len().max(1) as f64;
        if fill > self.policy.max_window_fill {
            return Err(SanityError::FrameEscape {
                claim_start: bs.meta.frames.start,
                claim_end: bs.meta.frames.end,
                win_start: region_window.start,
                win_end: region_window.end,
            });
        }
        if !bs.meta.resources.fits_in(region_capacity) {
            return Err(SanityError::TooLarge {
                needed: bs.meta.resources.to_string(),
                offered: region_capacity.to_string(),
            });
        }
        Ok(())
    }

    /// Validate a *full* bitstream (RSaaS or the RC2F basic design).
    pub fn check_full(
        &self,
        bs: &Bitstream,
        device_part: &str,
    ) -> Result<(), SanityError> {
        self.check_common(bs, device_part)?;
        if bs.kind != BitstreamKind::Full {
            return Err(SanityError::WrongKind {
                expected: BitstreamKind::Full,
                got: bs.kind,
            });
        }
        Ok(())
    }

    fn check_common(
        &self,
        bs: &Bitstream,
        device_part: &str,
    ) -> Result<(), SanityError> {
        if !bs.crc_ok() {
            return Err(SanityError::BadCrc);
        }
        if bs.meta.part != device_part {
            return Err(SanityError::WrongPart(
                bs.meta.part.clone(),
                device_part.to_string(),
            ));
        }
        if self.policy.require_signature {
            match &bs.signature {
                None => return Err(SanityError::Unsigned),
                Some(sig) => {
                    let expected = super::builder::sign(
                        &self.policy.provider_key,
                        &bs.sha256,
                    );
                    if *sig != expected {
                        return Err(SanityError::BadSignature);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::BitstreamBuilder;

    const PART: &str = "xc7vx485t";
    const WINDOW: FrameRange = FrameRange {
        start: 100,
        end: 200,
    };

    fn capacity() -> Resources {
        Resources::new(60_000, 120_000, 200, 560)
    }

    fn good_partial() -> Bitstream {
        BitstreamBuilder::partial(PART, "matmul16")
            .resources(Resources::new(25_298, 41_654, 14, 80))
            .frames(FrameRange {
                start: 110,
                end: 190,
            })
            .build()
    }

    fn checker() -> SanityChecker {
        SanityChecker::new(SanityPolicy::research())
    }

    #[test]
    fn accepts_well_formed_partial() {
        assert_eq!(
            checker().check_partial(&good_partial(), PART, WINDOW, capacity()),
            Ok(())
        );
    }

    #[test]
    fn rejects_corrupt_payload() {
        let mut bs = good_partial();
        bs.payload[3] ^= 0x40;
        assert_eq!(
            checker().check_partial(&bs, PART, WINDOW, capacity()),
            Err(SanityError::BadCrc)
        );
    }

    #[test]
    fn rejects_wrong_part() {
        let bs = good_partial();
        let err = checker()
            .check_partial(&bs, "xc6vlx240t", WINDOW, capacity())
            .unwrap_err();
        assert!(matches!(err, SanityError::WrongPart(..)));
    }

    #[test]
    fn rejects_frame_escape_low_and_high() {
        for frames in [
            FrameRange { start: 50, end: 150 },
            FrameRange {
                start: 150,
                end: 250,
            },
            FrameRange { start: 0, end: 300 },
        ] {
            let bs = BitstreamBuilder::partial(PART, "evil")
                .resources(Resources::new(1, 1, 1, 1))
                .frames(frames)
                .build();
            let err = checker()
                .check_partial(&bs, PART, WINDOW, capacity())
                .unwrap_err();
            assert!(
                matches!(err, SanityError::FrameEscape { .. }),
                "frames {frames:?} gave {err:?}"
            );
        }
    }

    #[test]
    fn rejects_empty_frame_claim() {
        let bs = BitstreamBuilder::partial(PART, "odd")
            .frames(FrameRange {
                start: 150,
                end: 150,
            })
            .build();
        assert_eq!(
            checker().check_partial(&bs, PART, WINDOW, capacity()),
            Err(SanityError::EmptyFrames)
        );
    }

    #[test]
    fn rejects_oversized_design() {
        let bs = BitstreamBuilder::partial(PART, "big")
            .resources(Resources::new(999_999, 1, 1, 1))
            .frames(FrameRange {
                start: 110,
                end: 120,
            })
            .build();
        let err = checker()
            .check_partial(&bs, PART, WINDOW, capacity())
            .unwrap_err();
        assert!(matches!(err, SanityError::TooLarge { .. }));
    }

    #[test]
    fn rejects_full_bitstream_in_partial_slot() {
        let bs = BitstreamBuilder::full(PART, "whole").build();
        let err = checker()
            .check_partial(&bs, PART, WINDOW, capacity())
            .unwrap_err();
        assert!(matches!(err, SanityError::WrongKind { .. }));
    }

    #[test]
    fn production_policy_requires_valid_signature() {
        let prod = SanityChecker::new(SanityPolicy::production());
        // Unsigned → rejected.
        let unsigned = good_partial();
        assert_eq!(
            prod.check_partial(&unsigned, PART, WINDOW, capacity()),
            Err(SanityError::Unsigned)
        );
        // Correctly signed → accepted.
        let signed = BitstreamBuilder::partial(PART, "matmul16")
            .resources(Resources::new(25_298, 41_654, 14, 80))
            .frames(FrameRange {
                start: 110,
                end: 190,
            })
            .signed_with("rc3e-provider")
            .build();
        assert_eq!(
            prod.check_partial(&signed, PART, WINDOW, capacity()),
            Ok(())
        );
        // Signed with the wrong key → rejected.
        let forged = BitstreamBuilder::partial(PART, "matmul16")
            .resources(Resources::new(25_298, 41_654, 14, 80))
            .frames(FrameRange {
                start: 110,
                end: 190,
            })
            .signed_with("attacker-key")
            .build();
        assert_eq!(
            prod.check_partial(&forged, PART, WINDOW, capacity()),
            Err(SanityError::BadSignature)
        );
    }

    #[test]
    fn check_full_accepts_and_rejects_kind() {
        let full = BitstreamBuilder::full(PART, "rsaas_user").build();
        assert_eq!(checker().check_full(&full, PART), Ok(()));
        let partial = good_partial();
        assert!(matches!(
            checker().check_full(&partial, PART),
            Err(SanityError::WrongKind { .. })
        ));
    }
}
