//! Bitstream container format + sanity checking.
//!
//! The paper (Section VI) names "sanity checking for (partial)
//! bitfiles to avoid both damage by a tampered bitstream and access to
//! the parts not reconfigurable by the users" as its most important
//! future-work item — we implement it as a first-class feature.
//!
//! A [`Bitstream`] is a synthetic but structurally faithful container:
//! a header with the target part and metadata (core name, resource
//! footprint, claimed frame range), a frame payload, a CRC32 per the
//! Xilinx config logic, and an optional provider signature (sha256
//! over header+payload keyed by the provider secret — stand-in for
//! the vendor signing flow).

pub mod builder;
pub mod sanity;

pub use builder::BitstreamBuilder;
pub use sanity::{SanityChecker, SanityError, SanityPolicy};

use crate::fpga::resources::Resources;
use crate::util::json::Json;

/// Full-device bitstream vs PR region bitstream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitstreamKind {
    Full,
    Partial,
}

impl BitstreamKind {
    pub fn name(self) -> &'static str {
        match self {
            BitstreamKind::Full => "full",
            BitstreamKind::Partial => "partial",
        }
    }
}

/// Frame-address range the bitstream claims to touch. The sanity
/// checker compares this against the region's allowed window — a
/// tampered bitstream that addresses frames outside its PR region is
/// exactly the attack the paper wants caught.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameRange {
    pub start: u64,
    pub end: u64, // exclusive
}

impl FrameRange {
    pub fn contains(self, other: FrameRange) -> bool {
        self.start <= other.start && other.end <= self.end
    }
    pub fn len(self) -> u64 {
        self.end.saturating_sub(self.start)
    }
    pub fn is_empty(self) -> bool {
        self.len() == 0
    }
}

/// Descriptive metadata carried in the container header.
#[derive(Debug, Clone, PartialEq)]
pub struct BitstreamMeta {
    /// Target FPGA part marking (e.g. "xc7vx485t").
    pub part: String,
    /// Core / design name (e.g. "matmul16", "rc2f_basic_4v").
    pub core: String,
    /// HLO artifact variant implementing the core's compute, if any
    /// (binds the simulated design to a real PJRT executable).
    pub artifact: Option<String>,
    /// Synthesized resource footprint.
    pub resources: Resources,
    /// Claimed configuration frame window.
    pub frames: FrameRange,
    /// For RC2F basic (full) designs: how many vFPGA regions it carves.
    pub vfpga_regions: Option<usize>,
}

/// A (synthetic) bitstream: header + frames + integrity data.
#[derive(Debug, Clone, PartialEq)]
pub struct Bitstream {
    pub kind: BitstreamKind,
    pub meta: BitstreamMeta,
    /// Frame payload (synthetic bytes; size models config time).
    pub payload: Vec<u8>,
    /// CRC32 over the payload (Xilinx config-logic style).
    pub crc32: u32,
    /// sha256 hex over header+payload — the identity the database and
    /// the region state reference.
    pub sha256: String,
    /// Provider signature (BAaaS bitfiles are provider-signed).
    pub signature: Option<String>,
}

impl Bitstream {
    /// Size in bytes (drives configuration-time modeling).
    pub fn size(&self) -> usize {
        self.payload.len()
    }

    /// Recompute the payload CRC and compare (integrity check).
    pub fn crc_ok(&self) -> bool {
        crate::util::hash::crc32(&self.payload) == self.crc32
    }

    /// Canonical header bytes (input to sha256/signature).
    pub fn header_bytes(meta: &BitstreamMeta, kind: BitstreamKind) -> Vec<u8> {
        let mut buf = Vec::new();
        crate::util::bytes::put_str(&mut buf, kind.name());
        crate::util::bytes::put_str(&mut buf, &meta.part);
        crate::util::bytes::put_str(&mut buf, &meta.core);
        crate::util::bytes::put_str(
            &mut buf,
            meta.artifact.as_deref().unwrap_or(""),
        );
        for v in [
            meta.resources.lut,
            meta.resources.ff,
            meta.resources.bram,
            meta.resources.dsp,
            meta.frames.start,
            meta.frames.end,
            meta.vfpga_regions.unwrap_or(0) as u64,
        ] {
            crate::util::bytes::put_u64(&mut buf, v);
        }
        buf
    }

    /// Full transfer/persistence encoding: every field, losslessly.
    /// With `include_payload` the frame payload rides inline as
    /// base64; pass `false` for transports that carry the payload
    /// out-of-band (protocol-4 `BIN` frames) or stores that keep it
    /// elsewhere, and supply it to [`Bitstream::from_transfer_json`].
    pub fn to_transfer_json(&self, include_payload: bool) -> Json {
        let mut pairs = vec![
            ("kind", Json::from(self.kind.name())),
            ("part", Json::from(self.meta.part.as_str())),
            ("core", Json::from(self.meta.core.as_str())),
            (
                "artifact",
                match &self.meta.artifact {
                    Some(a) => Json::from(a.as_str()),
                    None => Json::Null,
                },
            ),
            ("resources", self.meta.resources.to_json()),
            ("frames_start", Json::from(self.meta.frames.start)),
            ("frames_end", Json::from(self.meta.frames.end)),
            (
                "vfpga_regions",
                match self.meta.vfpga_regions {
                    Some(n) => Json::from(n),
                    None => Json::Null,
                },
            ),
            ("crc32", Json::from(self.crc32 as u64)),
            ("sha256", Json::from(self.sha256.as_str())),
            (
                "signature",
                match &self.signature {
                    Some(s) => Json::from(s.as_str()),
                    None => Json::Null,
                },
            ),
        ];
        if include_payload {
            pairs.push((
                "payload",
                Json::from(
                    crate::util::bytes::b64_encode(&self.payload)
                        .as_str(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    /// Decode a [`Bitstream::to_transfer_json`] body. The payload
    /// comes from `payload_oob` when the transport carried it
    /// out-of-band, else from the inline base64 `payload` field.
    pub fn from_transfer_json(
        v: &Json,
        payload_oob: Option<Vec<u8>>,
    ) -> Option<Bitstream> {
        let kind = match v.get("kind").as_str()? {
            "full" => BitstreamKind::Full,
            "partial" => BitstreamKind::Partial,
            _ => return None,
        };
        let payload = match payload_oob {
            Some(p) => p,
            None => crate::util::bytes::b64_decode(
                v.get("payload").as_str()?,
            )
            .ok()?,
        };
        Some(Bitstream {
            kind,
            meta: BitstreamMeta {
                part: v.get("part").as_str()?.to_string(),
                core: v.get("core").as_str()?.to_string(),
                artifact: v
                    .get("artifact")
                    .as_str()
                    .map(str::to_string),
                resources: Resources::from_json(v.get("resources"))?,
                frames: FrameRange {
                    start: v.get("frames_start").as_u64()?,
                    end: v.get("frames_end").as_u64()?,
                },
                vfpga_regions: v
                    .get("vfpga_regions")
                    .as_u64()
                    .map(|n| n as usize),
            },
            payload,
            crc32: v.get("crc32").as_u64()? as u32,
            sha256: v.get("sha256").as_str()?.to_string(),
            signature: v
                .get("signature")
                .as_str()
                .map(str::to_string),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::from(self.kind.name())),
            ("part", Json::from(self.meta.part.as_str())),
            ("core", Json::from(self.meta.core.as_str())),
            (
                "artifact",
                match &self.meta.artifact {
                    Some(a) => Json::from(a.as_str()),
                    None => Json::Null,
                },
            ),
            ("resources", self.meta.resources.to_json()),
            ("bytes", Json::from(self.payload.len())),
            ("sha256", Json::from(self.sha256.as_str())),
            ("signed", Json::from(self.signature.is_some())),
        ])
    }
}

/// Helpers shared by tests across modules (device, hypervisor, rc2f).
pub mod tests_support {
    use super::*;

    /// An RC2F basic design full bitstream carving `n` regions, with
    /// the Table II footprint for the chosen region count.
    pub fn rc2f_full_bs(part: &str, n: usize) -> Bitstream {
        let resources = match n {
            1 => Resources::new(7_082, 6_974, 13, 0),
            2 => Resources::new(7_807, 7_637, 17, 0),
            _ => Resources::new(8_532, 8_318, 25, 0),
        };
        BitstreamBuilder::full(part, &format!("rc2f_basic_{n}v"))
            .resources(resources)
            .vfpga_regions(n)
            .payload_len(1024)
            .build()
    }

    /// A quarter-region partial bitstream for a named core.
    pub fn partial_bs(part: &str, core: &str) -> Bitstream {
        BitstreamBuilder::partial(part, core)
            .resources(Resources::new(25_298, 41_654, 14, 80))
            .frames(FrameRange {
                start: 0,
                end: 100,
            })
            .payload_len(512)
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_range_containment() {
        let outer = FrameRange { start: 10, end: 50 };
        assert!(outer.contains(FrameRange { start: 10, end: 50 }));
        assert!(outer.contains(FrameRange { start: 20, end: 30 }));
        assert!(!outer.contains(FrameRange { start: 5, end: 20 }));
        assert!(!outer.contains(FrameRange { start: 40, end: 51 }));
        assert_eq!(outer.len(), 40);
        assert!(FrameRange { start: 3, end: 3 }.is_empty());
    }

    #[test]
    fn crc_detects_corruption() {
        let mut bs = tests_support::partial_bs("xc7vx485t", "m");
        assert!(bs.crc_ok());
        bs.payload[0] ^= 0xFF;
        assert!(!bs.crc_ok());
    }

    #[test]
    fn sha_identifies_content() {
        let a = tests_support::partial_bs("xc7vx485t", "core_a");
        let b = tests_support::partial_bs("xc7vx485t", "core_b");
        assert_ne!(a.sha256, b.sha256);
        assert_eq!(a.sha256.len(), 64);
    }

    #[test]
    fn transfer_json_roundtrips_inline_and_oob() {
        let bs = tests_support::partial_bs("xc7vx485t", "matmul16");
        // Inline payload (v3 base64 fallback / on-disk cache layout).
        let inline =
            Bitstream::from_transfer_json(&bs.to_transfer_json(true), None)
                .unwrap();
        assert_eq!(inline, bs);
        assert!(inline.crc_ok());
        // Out-of-band payload (protocol-4 BIN frames).
        let oob = Bitstream::from_transfer_json(
            &bs.to_transfer_json(false),
            Some(bs.payload.clone()),
        )
        .unwrap();
        assert_eq!(oob, bs);
        // A missing payload on both channels fails to decode.
        assert!(Bitstream::from_transfer_json(
            &bs.to_transfer_json(false),
            None
        )
        .is_none());
    }

    #[test]
    fn json_summary() {
        let bs = tests_support::rc2f_full_bs("xc7vx485t", 4);
        let j = bs.to_json();
        assert_eq!(j.get("kind").as_str().unwrap(), "full");
        assert_eq!(j.get("core").as_str().unwrap(), "rc2f_basic_4v");
    }
}
