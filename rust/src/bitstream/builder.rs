//! Bitstream construction (the output side of the HLS flow and the
//! provider's BAaaS bitfile store).

use super::{Bitstream, BitstreamKind, BitstreamMeta, FrameRange};
use crate::fpga::resources::Resources;
use crate::util::hash::{hex, Sha256};

/// Fluent builder for synthetic bitstreams.
#[derive(Debug)]
pub struct BitstreamBuilder {
    kind: BitstreamKind,
    meta: BitstreamMeta,
    payload_len: usize,
    sign_key: Option<String>,
    payload_seed: u64,
}

impl BitstreamBuilder {
    pub fn full(part: &str, core: &str) -> BitstreamBuilder {
        BitstreamBuilder::new(BitstreamKind::Full, part, core)
    }

    pub fn partial(part: &str, core: &str) -> BitstreamBuilder {
        BitstreamBuilder::new(BitstreamKind::Partial, part, core)
    }

    fn new(kind: BitstreamKind, part: &str, core: &str) -> BitstreamBuilder {
        BitstreamBuilder {
            kind,
            meta: BitstreamMeta {
                part: part.to_string(),
                core: core.to_string(),
                artifact: None,
                resources: Resources::ZERO,
                frames: FrameRange { start: 0, end: 1 },
                vfpga_regions: None,
            },
            payload_len: 256,
            sign_key: None,
            payload_seed: 0x5eed,
        }
    }

    /// Synthesized resource footprint.
    pub fn resources(mut self, r: Resources) -> Self {
        self.meta.resources = r;
        self
    }

    /// Claimed configuration-frame window.
    pub fn frames(mut self, f: FrameRange) -> Self {
        self.meta.frames = f;
        self
    }

    /// Bind to an HLO artifact variant (the real compute).
    pub fn artifact(mut self, name: &str) -> Self {
        self.meta.artifact = Some(name.to_string());
        self
    }

    /// Mark as an RC2F basic design carving `n` vFPGA regions.
    pub fn vfpga_regions(mut self, n: usize) -> Self {
        self.meta.vfpga_regions = Some(n);
        self
    }

    /// Synthetic payload size in bytes.
    pub fn payload_len(mut self, len: usize) -> Self {
        self.payload_len = len;
        self
    }

    /// Vary the payload content (distinct shas for equal metadata).
    pub fn payload_seed(mut self, seed: u64) -> Self {
        self.payload_seed = seed;
        self
    }

    /// Sign with the provider key (BAaaS bitfiles).
    pub fn signed_with(mut self, key: &str) -> Self {
        self.sign_key = Some(key.to_string());
        self
    }

    /// Finalize: generate payload, CRC, sha256 and signature.
    pub fn build(self) -> Bitstream {
        let mut rng = crate::util::rng::Rng::new(self.payload_seed);
        let payload: Vec<u8> = (0..self.payload_len)
            .map(|_| rng.next_u64() as u8)
            .collect();
        let crc32 = crate::util::hash::crc32(&payload);
        let header = Bitstream::header_bytes(&self.meta, self.kind);
        let mut hasher = Sha256::new();
        hasher.update(&header);
        hasher.update(&payload);
        let sha256 = hex(&hasher.finalize());
        let signature = self.sign_key.map(|key| sign(&key, &sha256));
        Bitstream {
            kind: self.kind,
            meta: self.meta,
            payload,
            crc32,
            sha256,
            signature,
        }
    }
}

/// Provider signature: sha256(key || content-sha). A stand-in for an
/// HMAC with the provider secret — what matters for the system is the
/// verify path, not the primitive.
pub fn sign(key: &str, content_sha: &str) -> String {
    let mut hasher = Sha256::new();
    hasher.update(key.as_bytes());
    hasher.update(content_sha.as_bytes());
    hex(&hasher.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults() {
        let bs = BitstreamBuilder::partial("xc7vx485t", "m").build();
        assert_eq!(bs.kind, BitstreamKind::Partial);
        assert_eq!(bs.payload.len(), 256);
        assert!(bs.crc_ok());
        assert!(bs.signature.is_none());
    }

    #[test]
    fn sha_covers_header() {
        let a = BitstreamBuilder::partial("xc7vx485t", "m").build();
        let b = BitstreamBuilder::partial("xc6vlx240t", "m").build();
        // Same payload seed, different part → different sha.
        assert_eq!(a.payload, b.payload);
        assert_ne!(a.sha256, b.sha256);
    }

    #[test]
    fn payload_seed_varies_content() {
        let a = BitstreamBuilder::partial("p", "c").payload_seed(1).build();
        let b = BitstreamBuilder::partial("p", "c").payload_seed(2).build();
        assert_ne!(a.payload, b.payload);
        assert_ne!(a.sha256, b.sha256);
    }

    #[test]
    fn signature_is_deterministic_per_key() {
        let a = BitstreamBuilder::partial("p", "c")
            .signed_with("provider-secret")
            .build();
        let b = BitstreamBuilder::partial("p", "c")
            .signed_with("provider-secret")
            .build();
        let c = BitstreamBuilder::partial("p", "c")
            .signed_with("other-key")
            .build();
        assert_eq!(a.signature, b.signature);
        assert_ne!(a.signature, c.signature);
    }

    #[test]
    fn artifact_binding() {
        let bs = BitstreamBuilder::partial("p", "matmul16")
            .artifact("matmul16_b256")
            .build();
        assert_eq!(bs.meta.artifact.as_deref(), Some("matmul16_b256"));
    }
}
