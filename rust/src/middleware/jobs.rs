//! Server-side registry for asynchronous RPC jobs.
//!
//! Long-running operations (`program_full`, `stream`,
//! `invoke_service`) used to block their connection thread for the
//! whole virtual-time duration of the work. On protocol ≥ 2 the
//! server instead submits the work here and answers immediately with
//! a job id; `job_status` / `job_wait` / `job_cancel` operate on the
//! registry. This is also the seam the ROADMAP's batch-pipelining
//! follow-up needs: once a long operation is a job, overlapping the
//! next job's PR with the previous job's streaming is a registry
//! policy, not an API change.
//!
//! Model: one worker thread per submitted job (the same
//! thread-per-unit idiom the server uses per connection), a
//! [`Condvar`] for waiters, and bounded terminal-state retention —
//! finished jobs stay queryable until [`RETAINED_TERMINAL`] newer
//! jobs have finished, then the oldest are evicted and read as
//! `unknown_job`.
//!
//! Cancellation is a state race the registry referees: `cancel` flips
//! a *running* job to `cancelled`; when the worker later finishes, a
//! cancelled job keeps its cancelled state and the worker's result is
//! discarded. Terminal states never change.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::api::{ApiError, ErrorCode, JobBody};
use crate::util::ids::{IdGen, JobId, LeaseToken};
use crate::util::json::Json;

/// Terminal jobs kept queryable after completion.
pub const RETAINED_TERMINAL: usize = 256;

/// Default server-side bound on one `job_wait` call (wall seconds).
pub const DEFAULT_WAIT_S: f64 = 60.0;

/// Hard cap on one `job_wait` call. Deliberately below the client
/// library's 120 s socket read timeout: a server wait that outlives
/// the client's read leaves a stale frame on the connection and
/// desynchronizes every later response. Longer waits are built by
/// retrying on the (retryable) `timeout` code.
pub const MAX_WAIT_S: f64 = 100.0;

/// One job's lifecycle state.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Running,
    Done(Json),
    Failed(ApiError),
    Cancelled,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Running)
    }
}

/// One tracked job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub id: JobId,
    /// RPC method the job runs ("stream", "program_full", ...).
    pub method: String,
    pub state: JobState,
    /// Virtual timestamp of submission.
    pub submitted_ns: u64,
    /// Capability token owning this job: the lease token presented
    /// at submission (or a fresh job-scoped token for leaseless
    /// operations). `None` = unowned (protocol-1 submissions) — no
    /// token gate applies.
    pub owner: Option<LeaseToken>,
}

impl JobRecord {
    /// Wire form for the `job_*` RPC responses.
    pub fn to_body(&self) -> JobBody {
        let (result, error) = match &self.state {
            JobState::Done(v) => (Some(v.clone()), None),
            JobState::Failed(e) => (None, Some(e.clone())),
            _ => (None, None),
        };
        JobBody {
            job: self.id,
            method: self.method.clone(),
            state: self.state.name().to_string(),
            result,
            error,
        }
    }
}

#[derive(Debug, Default)]
struct Jobs {
    records: BTreeMap<JobId, JobRecord>,
    /// Terminal jobs, oldest first (eviction order).
    terminal: VecDeque<JobId>,
}

/// The registry.
#[derive(Debug, Default)]
pub struct JobRegistry {
    state: Mutex<Jobs>,
    done: Condvar,
    ids: IdGen,
}

impl JobRegistry {
    pub fn new() -> Arc<JobRegistry> {
        Arc::new(JobRegistry::default())
    }

    /// Submit `work` as a new job; it runs on its own worker thread
    /// and the job id is returned immediately. Takes an owned `Arc`
    /// (the worker keeps the registry alive past the caller) — clone
    /// at the call site: `Arc::clone(&jobs).submit(...)`.
    pub fn submit(
        self: Arc<JobRegistry>,
        method: &str,
        submitted_ns: u64,
        owner: Option<LeaseToken>,
        work: impl FnOnce() -> Result<Json, ApiError> + Send + 'static,
    ) -> JobId {
        let id = JobId(self.ids.next());
        {
            let mut st = self.state.lock().unwrap();
            st.records.insert(
                id,
                JobRecord {
                    id,
                    method: method.to_string(),
                    state: JobState::Running,
                    submitted_ns,
                    owner,
                },
            );
        }
        std::thread::spawn(move || {
            let result = work();
            self.finish(id, result);
        });
        id
    }

    /// Record a worker's result. A job cancelled mid-flight keeps its
    /// cancelled state and the result is discarded.
    fn finish(&self, id: JobId, result: Result<Json, ApiError>) {
        let mut st = self.state.lock().unwrap();
        if let Some(rec) = st.records.get_mut(&id) {
            if rec.state == JobState::Running {
                rec.state = match result {
                    Ok(v) => JobState::Done(v),
                    Err(e) => JobState::Failed(e),
                };
                Self::retire(&mut st, id);
            }
        }
        self.done.notify_all();
    }

    /// Move a freshly-terminal job into the retention queue, evicting
    /// the oldest beyond [`RETAINED_TERMINAL`]. Call with the state
    /// lock held and only on a Running → terminal transition.
    fn retire(st: &mut Jobs, id: JobId) {
        st.terminal.push_back(id);
        while st.terminal.len() > RETAINED_TERMINAL {
            if let Some(old) = st.terminal.pop_front() {
                st.records.remove(&old);
            }
        }
    }

    fn unknown(id: JobId) -> ApiError {
        ApiError::new(
            ErrorCode::UnknownJob,
            format!("unknown job {id} (never existed, or evicted)"),
        )
    }

    /// Current record of a job.
    pub fn status(&self, id: JobId) -> Result<JobRecord, ApiError> {
        self.state
            .lock()
            .unwrap()
            .records
            .get(&id)
            .cloned()
            .ok_or_else(|| Self::unknown(id))
    }

    /// Block until the job reaches a terminal state, bounded by
    /// `timeout` of wall time. On expiry the job keeps running and
    /// the caller gets a retryable [`ErrorCode::Timeout`].
    pub fn wait(
        &self,
        id: JobId,
        timeout: Duration,
    ) -> Result<JobRecord, ApiError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            match st.records.get(&id) {
                None => return Err(Self::unknown(id)),
                Some(rec) if rec.state.is_terminal() => {
                    return Ok(rec.clone())
                }
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(ApiError::new(
                    ErrorCode::Timeout,
                    format!("{id} still running after {timeout:?}"),
                ));
            }
            let (guard, _) = self
                .done
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    /// Cancel a running job. Terminal jobs are returned unchanged (a
    /// cancel that lost the race to completion is not an error).
    pub fn cancel(&self, id: JobId) -> Result<JobRecord, ApiError> {
        let mut st = self.state.lock().unwrap();
        let Some(rec) = st.records.get_mut(&id) else {
            return Err(Self::unknown(id));
        };
        if rec.state == JobState::Running {
            rec.state = JobState::Cancelled;
            let cloned = rec.clone();
            Self::retire(&mut st, id);
            self.done.notify_all();
            return Ok(cloned);
        }
        Ok(rec.clone())
    }

    /// Number of jobs currently running (telemetry).
    pub fn running(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .records
            .values()
            .filter(|r| r.state == JobState::Running)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn submit_wait_returns_result() {
        let reg = JobRegistry::new();
        let id = Arc::clone(&reg).submit("stream", 0, None, || Ok(Json::from(42u64)));
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        assert_eq!(rec.state, JobState::Done(Json::Num(42.0)));
        assert_eq!(rec.method, "stream");
        // Terminal state is retained for status queries.
        let body = reg.status(id).unwrap().to_body();
        assert_eq!(body.state, "done");
        assert_eq!(body.into_done().unwrap(), Json::Num(42.0));
    }

    #[test]
    fn failed_job_carries_api_error() {
        let reg = JobRegistry::new();
        let id = Arc::clone(&reg).submit("program_full", 0, None, || {
            Err(ApiError::new(ErrorCode::NoCapacity, "full"))
        });
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        match rec.state {
            JobState::Failed(e) => {
                assert_eq!(e.code, ErrorCode::NoCapacity)
            }
            s => panic!("expected failure, got {s:?}"),
        }
    }

    #[test]
    fn unknown_job_is_typed_error() {
        let reg = JobRegistry::new();
        let err = reg.status(JobId(999)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
        let err = reg.wait(JobId(999), Duration::from_millis(1)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
        let err = reg.cancel(JobId(999)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
    }

    #[test]
    fn wait_times_out_on_stuck_job() {
        let reg = JobRegistry::new();
        let (tx, rx) = mpsc::channel::<()>();
        let id = Arc::clone(&reg).submit("stream", 0, None, move || {
            let _ = rx.recv(); // block until the test releases us
            Ok(Json::Null)
        });
        let err = reg.wait(id, Duration::from_millis(50)).unwrap_err();
        assert_eq!(err.code, ErrorCode::Timeout);
        assert!(err.retryable);
        drop(tx); // release the worker
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        assert!(rec.state.is_terminal());
    }

    #[test]
    fn cancel_beats_completion_and_sticks() {
        let reg = JobRegistry::new();
        let (tx, rx) = mpsc::channel::<()>();
        let id = Arc::clone(&reg).submit("stream", 0, None, move || {
            let _ = rx.recv();
            Ok(Json::from(1u64))
        });
        let rec = reg.cancel(id).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
        // Worker finishes after the cancel: result is discarded.
        tx.send(()).unwrap();
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
        // Cancelling a terminal job is a no-op, not an error.
        let rec = reg.cancel(id).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
    }

    #[test]
    fn terminal_retention_evicts_oldest() {
        let reg = JobRegistry::new();
        let mut first = None;
        for i in 0..(RETAINED_TERMINAL + 10) {
            let id = Arc::clone(&reg).submit("stream", 0, None, move || {
                Ok(Json::from(i as u64))
            });
            reg.wait(id, Duration::from_secs(5)).unwrap();
            first.get_or_insert(id);
        }
        // The very first job has been evicted; the newest survives.
        let err = reg.status(first.unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
        assert_eq!(reg.running(), 0);
    }
}
