//! Server-side registry for asynchronous RPC jobs.
//!
//! Long-running operations (`program_full`, `stream`,
//! `invoke_service`) used to block their connection thread for the
//! whole virtual-time duration of the work. The server submits the
//! work here and answers immediately with a job id; `job_status` /
//! `job_wait` / `job_cancel` operate on the registry. This is also
//! the seam the batch pipelining rides: once a long operation is a
//! job, overlapping the next job's PR with the previous job's
//! streaming is a registry policy, not an API change.
//!
//! Model: one worker thread per submitted job (the same
//! thread-per-unit idiom the server uses per connection), bounded
//! terminal-state retention — finished jobs stay queryable until
//! [`RETAINED_TERMINAL`] newer jobs have finished, then the oldest
//! are evicted and read as `unknown_job`.
//!
//! **Coalesced waits** (protocol 3): all `job_wait` callers parked on
//! one job share a single [`WaitSlot`] — the completion fans one
//! wakeup out to every waiter instead of N independent poll loops.
//! The `jobs.wait.coalesced` counter records how many waiters each
//! shared wakeup served (only when more than one shared it), so the
//! many-clients-one-job fan-in is observable.
//!
//! **Progress events** (protocol 3): workers receive a
//! [`ProgressReporter`] and emit [`Event::JobProgress`] frames at
//! phase boundaries and stream checkpoints; the registry itself
//! emits the `submitted` frame and the terminal frame — the latter
//! carries the *exact* job body `job_wait` returns, so a subscriber
//! needs no final poll.
//!
//! Cancellation is a state race the registry referees: `cancel` flips
//! a *running* job to `cancelled`; when the worker later finishes, a
//! cancelled job keeps its cancelled state and the worker's result is
//! discarded. Terminal states never change.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::api::{ApiError, ErrorCode, Event, JobBody};
use super::events::{EventBus, Scope};
use crate::metrics::Registry;
use crate::util::ids::{IdGen, JobId, LeaseToken, TraceId};
use crate::util::json::Json;
use crate::util::trace;

/// Terminal jobs kept queryable after completion.
pub const RETAINED_TERMINAL: usize = 256;

/// Default server-side bound on one `job_wait` call (wall seconds).
pub const DEFAULT_WAIT_S: f64 = 60.0;

/// Hard cap on one `job_wait` call. Deliberately below the client
/// library's 120 s socket read timeout: a server wait that outlives
/// the client's read leaves a stale frame on the connection and
/// desynchronizes every later response. Longer waits are built by
/// retrying on the (retryable) `timeout` code.
pub const MAX_WAIT_S: f64 = 100.0;

/// One job's lifecycle state.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Running,
    Done(Json),
    Failed(ApiError),
    Cancelled,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Running)
    }
}

/// One tracked job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub id: JobId,
    /// RPC method the job runs ("stream", "program_full", ...).
    pub method: String,
    pub state: JobState,
    /// Virtual timestamp of submission.
    pub submitted_ns: u64,
    /// Capability token owning this job: the lease token presented
    /// at submission (or a fresh job-scoped token for leaseless
    /// operations). `None` = unowned — no token gate applies and its
    /// progress events are public.
    pub owner: Option<LeaseToken>,
    /// Trace the submitting RPC ran under, if any. Progress events
    /// and `trace_get { job }` lookups correlate through this.
    pub trace: Option<TraceId>,
}

impl JobRecord {
    /// Wire form for the `job_*` RPC responses.
    pub fn to_body(&self) -> JobBody {
        let (result, error) = match &self.state {
            JobState::Done(v) => (Some(v.clone()), None),
            JobState::Failed(e) => (None, Some(e.clone())),
            _ => (None, None),
        };
        JobBody {
            job: self.id,
            method: self.method.clone(),
            state: self.state.name().to_string(),
            result,
            error,
            trace: self.trace,
        }
    }
}

/// The shared parking slot all `job_wait` callers of one job coalesce
/// on: one completion fanout wakes every waiter.
#[derive(Debug, Default)]
struct WaitSlot {
    state: Mutex<SlotState>,
    done: Condvar,
}

#[derive(Debug, Default)]
struct SlotState {
    /// Filled exactly once, at the job's terminal transition.
    result: Option<JobRecord>,
    /// Callers currently parked on this slot.
    waiters: u64,
}

#[derive(Debug, Default)]
struct Jobs {
    records: BTreeMap<JobId, JobRecord>,
    /// Terminal jobs, oldest first (eviction order).
    terminal: VecDeque<JobId>,
    /// Coalescing slots of running jobs with at least one waiter.
    slots: BTreeMap<JobId, Arc<WaitSlot>>,
}

/// The registry.
#[derive(Debug, Default)]
pub struct JobRegistry {
    state: Mutex<Jobs>,
    ids: IdGen,
    /// Wired by the server: `jobs.wait.coalesced` etc. land here.
    metrics: Mutex<Option<Arc<Registry>>>,
    /// Wired by the server: progress events are published here.
    bus: Mutex<Option<Arc<EventBus>>>,
}

/// Handed to every job worker: emits `JobProgress` frames at phase
/// boundaries / stream checkpoints without exposing the registry.
pub struct ProgressReporter {
    registry: Arc<JobRegistry>,
    id: JobId,
}

impl ProgressReporter {
    pub fn job(&self) -> JobId {
        self.id
    }

    /// Emit one mid-job progress frame (`state: "running"`).
    pub fn report(&self, phase: &str, bytes_streamed: u64, pct: f64) {
        self.registry.progress(self.id, phase, bytes_streamed, pct);
    }
}

impl JobRegistry {
    pub fn new() -> Arc<JobRegistry> {
        Arc::new(JobRegistry::default())
    }

    /// Wire a metrics registry (wait-coalescing counters).
    pub fn set_metrics(&self, metrics: Arc<Registry>) {
        *self.metrics.lock().unwrap() = Some(metrics);
    }

    /// Wire the event bus progress frames are published to.
    pub fn set_bus(&self, bus: Arc<EventBus>) {
        *self.bus.lock().unwrap() = Some(bus);
    }

    fn with_metrics(&self, f: impl FnOnce(&Registry)) {
        if let Some(m) = self.metrics.lock().unwrap().as_ref() {
            f(m);
        }
    }

    /// Publish a job event scoped to its owner token (public when
    /// unowned).
    fn publish(&self, owner: Option<LeaseToken>, event: Event) {
        let bus = self.bus.lock().unwrap().clone();
        if let Some(bus) = bus {
            let scope = match owner {
                Some(t) => Scope::Token(t),
                None => Scope::Public,
            };
            bus.publish(event, scope);
        }
    }

    /// Submit `work` as a new job; it runs on its own worker thread
    /// and the job id is returned immediately. The worker receives a
    /// [`ProgressReporter`] for mid-job frames. Takes an owned `Arc`
    /// (the worker keeps the registry alive past the caller) — clone
    /// at the call site: `Arc::clone(&jobs).submit(...)`.
    pub fn submit(
        self: Arc<JobRegistry>,
        method: &str,
        submitted_ns: u64,
        owner: Option<LeaseToken>,
        work: impl FnOnce(&ProgressReporter) -> Result<Json, ApiError>
            + Send
            + 'static,
    ) -> JobId {
        let id = JobId(self.ids.next());
        // Capture the submitting thread's trace context: the worker
        // adopts it so the async job stays in the submitter's trace.
        let ctx = trace::current();
        let trace = ctx.as_ref().map(|c| c.trace());
        {
            let mut st = self.state.lock().unwrap();
            st.records.insert(
                id,
                JobRecord {
                    id,
                    method: method.to_string(),
                    state: JobState::Running,
                    submitted_ns,
                    owner,
                    trace,
                },
            );
        }
        self.publish(
            owner,
            Event::JobProgress {
                job: id,
                method: method.to_string(),
                phase: "submitted".to_string(),
                bytes_streamed: 0,
                pct: 0.0,
                state: "running".to_string(),
                result: None,
                trace,
            },
        );
        let method_name = method.to_string();
        std::thread::spawn(move || {
            let job_span =
                ctx.map(|c| c.adopt(&format!("job.{method_name}")));
            let reporter = ProgressReporter {
                registry: Arc::clone(&self),
                id,
            };
            let result = work(&reporter);
            if let (Some(s), Err(e)) = (&job_span, &result) {
                s.fail(&e.message);
            }
            self.finish(id, result);
        });
        id
    }

    /// Emit one mid-job progress frame for a still-running job.
    /// Published while the registry lock is held (publish is an O(1)
    /// channel send), so a cancel racing the worker can never slip a
    /// terminal frame *under* this one — the terminal frame is always
    /// the stream's last word for a job.
    pub fn progress(
        &self,
        id: JobId,
        phase: &str,
        bytes_streamed: u64,
        pct: f64,
    ) {
        let st = self.state.lock().unwrap();
        let (method, owner, trace) = match st.records.get(&id) {
            Some(rec) if rec.state == JobState::Running => {
                (rec.method.clone(), rec.owner, rec.trace)
            }
            // Terminal or unknown: the terminal frame already told
            // the full story; stay silent.
            _ => return,
        };
        self.publish(
            owner,
            Event::JobProgress {
                job: id,
                method,
                phase: phase.to_string(),
                bytes_streamed,
                pct: pct.clamp(0.0, 100.0),
                state: "running".to_string(),
                result: None,
                trace,
            },
        );
        drop(st);
    }

    /// Record a worker's result. A job cancelled mid-flight keeps its
    /// cancelled state and the result is discarded.
    fn finish(&self, id: JobId, result: Result<Json, ApiError>) {
        let mut st = self.state.lock().unwrap();
        let Some(rec) = st.records.get_mut(&id) else { return };
        if rec.state != JobState::Running {
            return;
        }
        rec.state = match result {
            Ok(v) => JobState::Done(v),
            Err(e) => JobState::Failed(e),
        };
        self.settle_locked(st, id);
    }

    /// Shared Running → terminal bookkeeping: retention, the single
    /// coalesced waiter fanout, and the terminal progress frame. Call
    /// with the state lock held and the record already terminal. The
    /// terminal frame is published *under* the same lock
    /// [`JobRegistry::progress`] publishes under, so it is totally
    /// ordered after every mid-job frame — a subscriber never sees a
    /// stale `running` frame after the terminal one, whichever of
    /// cancel/completion wins the race.
    fn settle_locked(&self, mut st: std::sync::MutexGuard<'_, Jobs>, id: JobId) {
        let rec = st.records.get(&id).cloned().expect("settled record");
        Self::retire(&mut st, id);
        let slot = st.slots.remove(&id);
        // Terminal frame: the exact body `job_wait` returns, so a
        // subscriber needs no final poll.
        let body = rec.to_body();
        let bytes = body
            .result
            .as_ref()
            .and_then(|r| r.get("output_bytes").as_u64())
            .unwrap_or(0);
        self.publish(
            rec.owner,
            Event::JobProgress {
                job: id,
                method: rec.method.clone(),
                phase: rec.state.name().to_string(),
                bytes_streamed: bytes,
                pct: 100.0,
                state: rec.state.name().to_string(),
                result: Some(body.to_json()),
                trace: rec.trace,
            },
        );
        drop(st);
        if let Some(slot) = slot {
            let mut s = slot.state.lock().unwrap();
            s.result = Some(rec.clone());
            let waiters = s.waiters;
            drop(s);
            if waiters > 1 {
                // One wakeup served `waiters` parked callers.
                self.with_metrics(|m| {
                    m.counter("jobs.wait.coalesced").add(waiters)
                });
            }
            slot.done.notify_all();
        }
    }

    /// Move a freshly-terminal job into the retention queue, evicting
    /// the oldest beyond [`RETAINED_TERMINAL`]. Call with the state
    /// lock held and only on a Running → terminal transition.
    fn retire(st: &mut Jobs, id: JobId) {
        st.terminal.push_back(id);
        while st.terminal.len() > RETAINED_TERMINAL {
            if let Some(old) = st.terminal.pop_front() {
                st.records.remove(&old);
            }
        }
    }

    fn unknown(id: JobId) -> ApiError {
        ApiError::new(
            ErrorCode::UnknownJob,
            format!("unknown job {id} (never existed, or evicted)"),
        )
    }

    /// Current record of a job.
    pub fn status(&self, id: JobId) -> Result<JobRecord, ApiError> {
        self.state
            .lock()
            .unwrap()
            .records
            .get(&id)
            .cloned()
            .ok_or_else(|| Self::unknown(id))
    }

    /// Block until the job reaches a terminal state, bounded by
    /// `timeout` of wall time. On expiry the job keeps running and
    /// the caller gets a retryable [`ErrorCode::Timeout`]. All
    /// waiters of one job park on a shared [`WaitSlot`]; the
    /// completion wakes them with a single fanout.
    pub fn wait(
        &self,
        id: JobId,
        timeout: Duration,
    ) -> Result<JobRecord, ApiError> {
        let deadline = Instant::now() + timeout;
        // Fast path + slot registration under the registry lock (the
        // completion path takes the same lock before it removes the
        // slot, so a slot registered here is always woken).
        let slot = {
            let mut st = self.state.lock().unwrap();
            match st.records.get(&id) {
                None => return Err(Self::unknown(id)),
                Some(rec) if rec.state.is_terminal() => {
                    return Ok(rec.clone())
                }
                Some(_) => {}
            }
            let slot = Arc::clone(
                st.slots.entry(id).or_insert_with(Arc::default),
            );
            slot.state.lock().unwrap().waiters += 1;
            slot
        };
        let mut s = slot.state.lock().unwrap();
        loop {
            if let Some(rec) = &s.result {
                let rec = rec.clone();
                s.waiters -= 1;
                return Ok(rec);
            }
            let now = Instant::now();
            if now >= deadline {
                s.waiters -= 1;
                return Err(ApiError::new(
                    ErrorCode::Timeout,
                    format!("{id} still running after {timeout:?}"),
                ));
            }
            let (guard, _) =
                slot.done.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }

    /// Cancel a running job. Terminal jobs are returned unchanged (a
    /// cancel that lost the race to completion is not an error).
    pub fn cancel(&self, id: JobId) -> Result<JobRecord, ApiError> {
        let mut st = self.state.lock().unwrap();
        let Some(rec) = st.records.get_mut(&id) else {
            return Err(Self::unknown(id));
        };
        if rec.state == JobState::Running {
            rec.state = JobState::Cancelled;
            let cloned = rec.clone();
            self.settle_locked(st, id);
            return Ok(cloned);
        }
        Ok(rec.clone())
    }

    /// Callers currently parked on `id`'s coalescing slot
    /// (telemetry, tests).
    pub fn waiters(&self, id: JobId) -> u64 {
        self.state
            .lock()
            .unwrap()
            .slots
            .get(&id)
            .map(|s| s.state.lock().unwrap().waiters)
            .unwrap_or(0)
    }

    /// Number of jobs currently running (telemetry).
    pub fn running(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .records
            .values()
            .filter(|r| r.state == JobState::Running)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn submit_wait_returns_result() {
        let reg = JobRegistry::new();
        let id = Arc::clone(&reg)
            .submit("stream", 0, None, |_p| Ok(Json::from(42u64)));
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        assert_eq!(rec.state, JobState::Done(Json::Num(42.0)));
        assert_eq!(rec.method, "stream");
        // Terminal state is retained for status queries.
        let body = reg.status(id).unwrap().to_body();
        assert_eq!(body.state, "done");
        assert_eq!(body.into_done().unwrap(), Json::Num(42.0));
    }

    #[test]
    fn failed_job_carries_api_error() {
        let reg = JobRegistry::new();
        let id = Arc::clone(&reg).submit("program_full", 0, None, |_p| {
            Err(ApiError::new(ErrorCode::NoCapacity, "full"))
        });
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        match rec.state {
            JobState::Failed(e) => {
                assert_eq!(e.code, ErrorCode::NoCapacity)
            }
            s => panic!("expected failure, got {s:?}"),
        }
    }

    #[test]
    fn unknown_job_is_typed_error() {
        let reg = JobRegistry::new();
        let err = reg.status(JobId(999)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
        let err = reg.wait(JobId(999), Duration::from_millis(1)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
        let err = reg.cancel(JobId(999)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
    }

    #[test]
    fn wait_times_out_on_stuck_job() {
        let reg = JobRegistry::new();
        let (tx, rx) = mpsc::channel::<()>();
        let id = Arc::clone(&reg).submit("stream", 0, None, move |_p| {
            let _ = rx.recv(); // block until the test releases us
            Ok(Json::Null)
        });
        let err = reg.wait(id, Duration::from_millis(50)).unwrap_err();
        assert_eq!(err.code, ErrorCode::Timeout);
        assert!(err.retryable);
        drop(tx); // release the worker
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        assert!(rec.state.is_terminal());
    }

    #[test]
    fn cancel_beats_completion_and_sticks() {
        let reg = JobRegistry::new();
        let (tx, rx) = mpsc::channel::<()>();
        let id = Arc::clone(&reg).submit("stream", 0, None, move |_p| {
            let _ = rx.recv();
            Ok(Json::from(1u64))
        });
        let rec = reg.cancel(id).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
        // Worker finishes after the cancel: result is discarded.
        tx.send(()).unwrap();
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
        // Cancelling a terminal job is a no-op, not an error.
        let rec = reg.cancel(id).unwrap();
        assert_eq!(rec.state, JobState::Cancelled);
    }

    #[test]
    fn terminal_retention_evicts_oldest() {
        let reg = JobRegistry::new();
        let mut first = None;
        for i in 0..(RETAINED_TERMINAL + 10) {
            let id = Arc::clone(&reg).submit("stream", 0, None, move |_p| {
                Ok(Json::from(i as u64))
            });
            reg.wait(id, Duration::from_secs(5)).unwrap();
            first.get_or_insert(id);
        }
        // The very first job has been evicted; the newest survives.
        let err = reg.status(first.unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
        assert_eq!(reg.running(), 0);
    }

    #[test]
    fn coalesced_wait_wakes_all_waiters_with_one_fanout() {
        let metrics = Arc::new(Registry::new());
        let reg = JobRegistry::new();
        reg.set_metrics(Arc::clone(&metrics));
        let (tx, rx) = mpsc::channel::<()>();
        let id = Arc::clone(&reg).submit("stream", 0, None, move |_p| {
            let _ = rx.recv();
            Ok(Json::from(7u64))
        });
        let waiters: Vec<_> = (0..16)
            .map(|_| {
                let reg = Arc::clone(&reg);
                std::thread::spawn(move || {
                    reg.wait(id, Duration::from_secs(30)).unwrap()
                })
            })
            .collect();
        // Let every waiter park on the shared slot, then complete.
        while reg.waiters(id) < 16 {
            std::thread::sleep(Duration::from_millis(1));
        }
        tx.send(()).unwrap();
        for w in waiters {
            let rec = w.join().unwrap();
            assert_eq!(rec.state, JobState::Done(Json::Num(7.0)));
        }
        // One fanout served all 16 parked callers.
        assert_eq!(metrics.counter("jobs.wait.coalesced").get(), 16);
        // The slot is gone — no leak per completed job.
        assert!(reg.state.lock().unwrap().slots.is_empty());
    }

    #[test]
    fn progress_frames_flow_to_the_bus_in_order() {
        use super::super::api::{SubscriptionFilter, Topic};
        let bus = EventBus::new();
        let reg = JobRegistry::new();
        reg.set_bus(Arc::clone(&bus));
        let sub = bus.subscribe(
            SubscriptionFilter::topic(Topic::Job),
            None,
            None,
        );
        let id = Arc::clone(&reg).submit("stream", 0, None, |p| {
            p.report("streaming", 1024, 50.0);
            Ok(Json::obj(vec![("output_bytes", Json::from(2048u64))]))
        });
        let rec = reg.wait(id, Duration::from_secs(5)).unwrap();
        // submitted → streaming → done, strictly in publish order.
        let phases: Vec<String> = std::iter::from_fn(|| {
            sub.next(Duration::from_millis(500)).map(|e| match e {
                Event::JobProgress { phase, .. } => phase,
                other => panic!("unexpected event {other:?}"),
            })
        })
        .collect();
        assert_eq!(phases, ["submitted", "streaming", "done"]);
        // The terminal frame carried the exact job body.
        drop(rec);
    }
}
