//! Node agent: the per-FPGA-node daemon.
//!
//! Runs on every node that hosts boards; the management server routes
//! device-local operations (status queries, in a full deployment also
//! configuration writes) through the agent over TCP — the paper's
//! management-node → node hop over Gigabit Ethernet.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::proto::{read_frame, write_frame, Request, Response};
use crate::hypervisor::Hypervisor;
use crate::util::ids::{FpgaId, NodeId};
use crate::util::json::Json;

/// A running node agent (owns its listener thread).
pub struct NodeAgent {
    pub node: NodeId,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl NodeAgent {
    /// Spawn an agent for `node`, serving device ops from the shared
    /// hypervisor state (the process model is simulated; the wire is
    /// real TCP on loopback).
    pub fn spawn(
        hv: Arc<Hypervisor>,
        node: NodeId,
        fail_plan: Option<Arc<crate::testing::FailPlan>>,
    ) -> std::io::Result<NodeAgent> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let hv = Arc::clone(&hv);
                let plan = fail_plan.clone();
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, hv, node, plan);
                });
            }
        });
        Ok(NodeAgent {
            node,
            addr,
            stop,
            handle: Some(handle),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting (kicks the listener with a dummy connection).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeAgent {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    mut stream: TcpStream,
    hv: Arc<Hypervisor>,
    node: NodeId,
    plan: Option<Arc<crate::testing::FailPlan>>,
) -> std::io::Result<()> {
    while let Some(frame) = read_frame(&mut stream)? {
        if let Some(p) = &plan {
            if p.should_fail("agent.drop_conn") {
                // Simulated agent crash mid-request.
                stream.flush()?;
                return Ok(());
            }
        }
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::error(&e),
            Ok(req) => dispatch(&hv, node, &req),
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

fn dispatch(hv: &Hypervisor, node: NodeId, req: &Request) -> Response {
    match req.method.as_str() {
        "agent.hello" => Response::success(Json::obj(vec![
            ("node", Json::from(node.to_string())),
            ("version", Json::from(crate::VERSION)),
        ])),
        "agent.status" => {
            let Ok(fpga_str) = req.params.str_field("fpga") else {
                return Response::error("missing fpga");
            };
            let Some(fpga) = FpgaId::parse(fpga_str) else {
                return Response::error("bad fpga id");
            };
            // The agent performs the *local* status call (Table I's
            // 11 ms path); the management server adds the RPC charge.
            match hv.status_local(fpga) {
                Ok(st) => Response::success(Json::obj(vec![
                    ("fpga", Json::from(st.fpga.to_string())),
                    ("board", Json::from(st.board)),
                    (
                        "static_design",
                        st.static_design
                            .map(Json::from)
                            .unwrap_or(Json::Null),
                    ),
                    ("regions_total", Json::from(st.regions_total)),
                    (
                        "regions_configured",
                        Json::from(st.regions_configured),
                    ),
                    ("regions_clocked", Json::from(st.regions_clocked)),
                    ("power_w", Json::from(st.power_w)),
                ])),
                Err(e) => Response::error(&e.to_string()),
            }
        }
        m => Response::error(&format!("agent: unknown method '{m}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::middleware::client::Client;
    use crate::util::clock::VirtualClock;

    fn hv() -> Arc<Hypervisor> {
        Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap())
    }

    #[test]
    fn agent_serves_status_over_tcp() {
        let hv = hv();
        let agent = NodeAgent::spawn(Arc::clone(&hv), NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let body = client
            .call(
                "agent.status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        assert_eq!(body.get("regions_total").as_u64(), Some(4));
        assert_eq!(body.get("board").as_str(), Some("vc707"));
    }

    #[test]
    fn agent_hello_reports_node() {
        let hv = hv();
        let agent =
            NodeAgent::spawn(Arc::clone(&hv), NodeId(1), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let body = client.call("agent.hello", Json::obj(vec![])).unwrap();
        assert_eq!(body.get("node").as_str(), Some("node-1"));
    }

    #[test]
    fn unknown_method_is_error() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        assert!(client.call("agent.reboot", Json::obj(vec![])).is_err());
    }

    #[test]
    fn bad_fpga_id_is_error_not_crash() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        assert!(client
            .call(
                "agent.status",
                Json::obj(vec![("fpga", Json::from("fpga-99"))])
            )
            .is_err());
        // Connection still usable after the error.
        assert!(client.call("agent.hello", Json::obj(vec![])).is_ok());
    }

    #[test]
    fn injected_connection_drop_surfaces_as_io_error() {
        let hv = hv();
        let plan = crate::testing::FailPlan::new();
        plan.arm("agent.drop_conn", crate::testing::FailPoint::OnHit(1));
        let agent = NodeAgent::spawn(hv, NodeId(0), Some(plan)).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let err = client.call("agent.hello", Json::obj(vec![])).unwrap_err();
        assert!(err.contains("io") || err.contains("eof"), "{err}");
        // Reconnect works (the node came back).
        let mut c2 = Client::connect(agent.addr()).unwrap();
        assert!(c2.call("agent.hello", Json::obj(vec![])).is_ok());
    }
}
