//! Node agent: the per-FPGA-node daemon (compatibility shim).
//!
//! The agent grew into the cluster-federation subsystem and now
//! lives at [`crate::cluster::node`] — [`NodeAgent`] is the original
//! shared-hypervisor status agent, and its federated sibling
//! [`crate::cluster::node::NodeDaemon`] owns a whole node (local
//! hypervisor, devices, scheduler WAL, event journal) and serves the
//! full `agent.*` method surface. This module re-exports the agent
//! so existing `middleware::agent::NodeAgent` paths keep working.

pub use crate::cluster::node::NodeAgent;
