//! Node agent: the per-FPGA-node daemon.
//!
//! Runs on every node that hosts boards; the management server routes
//! device-local operations (status queries, in a full deployment also
//! configuration writes) through the agent over TCP — the paper's
//! management-node → node hop over Gigabit Ethernet.
//!
//! The agent speaks the same typed, versioned envelopes as the
//! management server ([`super::api`]): its two methods
//! ([`Method::AgentHello`], [`Method::AgentStatus`]) dispatch through
//! typed request/response structs. Protocol 1 is retired here too —
//! proto-less requests are rejected with `protocol_mismatch`.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::api::{
    AgentHelloRequest, AgentHelloResponse, ApiError, Method,
    StatusRequest, StatusResponse,
};
use super::proto::{read_frame, respond, write_frame, Request, Response};
use crate::hypervisor::Hypervisor;
use crate::util::ids::NodeId;
use crate::util::json::Json;

/// A running node agent (owns its listener thread).
pub struct NodeAgent {
    pub node: NodeId,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl NodeAgent {
    /// Spawn an agent for `node`, serving device ops from the shared
    /// hypervisor state (the process model is simulated; the wire is
    /// real TCP on loopback).
    pub fn spawn(
        hv: Arc<Hypervisor>,
        node: NodeId,
        fail_plan: Option<Arc<crate::testing::FailPlan>>,
    ) -> std::io::Result<NodeAgent> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let hv = Arc::clone(&hv);
                let plan = fail_plan.clone();
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, hv, node, plan);
                });
            }
        });
        Ok(NodeAgent {
            node,
            addr,
            stop,
            handle: Some(handle),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting (kicks the listener with a dummy connection).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeAgent {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    mut stream: TcpStream,
    hv: Arc<Hypervisor>,
    node: NodeId,
    plan: Option<Arc<crate::testing::FailPlan>>,
) -> std::io::Result<()> {
    while let Some(frame) = read_frame(&mut stream)? {
        if let Some(p) = &plan {
            if p.should_fail("agent.drop_conn") {
                // Simulated agent crash mid-request.
                stream.flush()?;
                return Ok(());
            }
        }
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::failure(None, ApiError::bad_request(e)),
            Ok(req) => {
                let result = req.negotiate_proto().and_then(|_| {
                    dispatch(&hv, node, &req.method, &req.params)
                });
                respond(req.id, result)
            }
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

fn dispatch(
    hv: &Hypervisor,
    node: NodeId,
    method: &str,
    params: &Json,
) -> Result<Json, ApiError> {
    match Method::parse(method) {
        Some(Method::AgentHello) => {
            let _req = AgentHelloRequest::from_json(params)?;
            Ok(AgentHelloResponse {
                node,
                version: crate::VERSION.to_string(),
            }
            .to_json())
        }
        Some(Method::AgentStatus) => {
            let req = StatusRequest::from_json(params)?;
            // The agent performs the *local* status call (Table I's
            // 11 ms path); the management server adds the RPC charge.
            let st =
                hv.status_local(req.fpga).map_err(ApiError::from)?;
            Ok(StatusResponse::from_status(&st).to_json())
        }
        _ => Err(ApiError::new(
            super::api::ErrorCode::UnknownMethod,
            format!("agent: unknown method '{method}'"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::middleware::client::Client;
    use crate::util::clock::VirtualClock;
    use crate::util::ids::FpgaId;

    fn hv() -> Arc<Hypervisor> {
        Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap())
    }

    #[test]
    fn agent_serves_status_over_tcp() {
        let hv = hv();
        let agent = NodeAgent::spawn(Arc::clone(&hv), NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let body = client
            .call_v2(
                "agent.status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        assert_eq!(body.get("regions_total").as_u64(), Some(4));
        assert_eq!(body.get("board").as_str(), Some("vc707"));
    }

    #[test]
    fn agent_rejects_retired_protocol_1() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut stream =
            TcpStream::connect(agent.addr()).unwrap();
        let raw = Json::obj(vec![
            ("method", Json::from("agent.hello")),
            ("params", Json::obj(vec![])),
        ]);
        super::write_frame(&mut stream, &raw).unwrap();
        let frame =
            super::read_frame(&mut stream).unwrap().unwrap();
        let err = Response::from_json(&frame)
            .unwrap()
            .into_api_result()
            .unwrap_err();
        assert_eq!(
            err.code,
            super::super::api::ErrorCode::ProtocolMismatch
        );
    }

    #[test]
    fn agent_serves_typed_status() {
        let hv = hv();
        let agent =
            NodeAgent::spawn(Arc::clone(&hv), NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let st = client.agent_status(FpgaId(0)).unwrap();
        assert_eq!(st.regions_total, 4);
        assert_eq!(st.board, "vc707");
        let hello = client.agent_hello().unwrap();
        assert_eq!(hello.node, NodeId(0));
        assert_eq!(hello.version, crate::VERSION);
    }

    #[test]
    fn agent_hello_reports_node() {
        let hv = hv();
        let agent =
            NodeAgent::spawn(Arc::clone(&hv), NodeId(1), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let hello = client.agent_hello().unwrap();
        assert_eq!(hello.node, NodeId(1));
    }

    #[test]
    fn unknown_method_is_error() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        assert!(client
            .call_v2("agent.reboot", Json::obj(vec![]))
            .is_err());
    }

    #[test]
    fn bad_fpga_id_is_error_not_crash() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        assert!(client
            .call_v2(
                "agent.status",
                Json::obj(vec![("fpga", Json::from("fpga-99"))])
            )
            .is_err());
        // Connection still usable after the error.
        assert!(client.agent_hello().is_ok());
    }

    #[test]
    fn injected_connection_drop_surfaces_as_io_error() {
        let hv = hv();
        let plan = crate::testing::FailPlan::new();
        plan.arm("agent.drop_conn", crate::testing::FailPoint::OnHit(1));
        let agent = NodeAgent::spawn(hv, NodeId(0), Some(plan)).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let err = client.agent_hello().unwrap_err();
        assert!(
            err.message.contains("io") || err.message.contains("eof"),
            "{err}"
        );
        // Reconnect works (the node came back).
        let mut c2 = Client::connect(agent.addr()).unwrap();
        assert!(c2.agent_hello().is_ok());
    }
}
