//! The management-node server: the middleware entry point users talk
//! to (the CLI connects here).
//!
//! Every incoming request charges the cluster's RPC overhead to the
//! virtual clock (Table I: the RC3E hop turns an 11 ms local status
//! call into 80 ms) and then dispatches through a table of *typed*
//! handlers — one [`Method`] → handler entry per RPC, each parsing a
//! typed request struct from [`super::api`] and serializing a typed
//! response. No handler reads raw params inline, and every failure
//! leaves the server as a structured [`ApiError`].
//!
//! Long-running operations (`program_full`, `stream`,
//! `invoke_service`) run synchronously for protocol-1 clients and as
//! registry jobs ([`super::jobs`]) for protocol-2 clients, which get
//! a `job_id` back immediately and drive `job_status` / `job_wait` /
//! `job_cancel`.
//!
//! Device status is routed through the owning node's
//! [`super::NodeAgent`] when one is registered — the management→node
//! Ethernet hop.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::api::*;
use super::client::Client;
use super::jobs::{JobRegistry, DEFAULT_WAIT_S, MAX_WAIT_S};
use super::proto::{read_frame, respond, write_frame, Request, Response};
use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::fpga::board::BoardKind;
use crate::hls::synth::{CoreKind, CoreSpec, Synthesizer};
use crate::hypervisor::{AllocKind, Hypervisor, HypervisorError};
use crate::rc2f::stream::StreamConfig;
use crate::sched::{
    AdmissionRequest, Lease, RequestClass, SchedError, Scheduler,
};
use crate::util::clock::VirtualTime;
use crate::util::ids::{AllocationId, LeaseToken, NodeId};
use crate::util::json::Json;

/// The management server (owns its accept thread).
pub struct ManagementServer {
    inner: Arc<ServerInner>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

struct ServerInner {
    hv: Arc<Hypervisor>,
    /// The cluster scheduler — every allocation RPC admits through it.
    sched: Arc<Scheduler>,
    /// Async jobs for the long-running RPCs (protocol ≥ 2).
    jobs: Arc<JobRegistry>,
    rpc_overhead_ms: f64,
    /// Prebuilt relocatable user-core bitfiles ("the user uploads a
    /// bitfile" — kept server-side so the CLI can reference cores by
    /// name).
    cores: BTreeMap<String, Bitstream>,
    /// node → agent address for routed device ops.
    agents: Mutex<BTreeMap<NodeId, SocketAddr>>,
}

impl ManagementServer {
    /// Spawn on an ephemeral loopback port.
    pub fn spawn(
        hv: Arc<Hypervisor>,
        rpc_overhead_ms: f64,
    ) -> std::io::Result<ManagementServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let sched = Scheduler::new(Arc::clone(&hv));
        let inner = Arc::new(ServerInner {
            hv,
            sched,
            jobs: JobRegistry::new(),
            rpc_overhead_ms,
            cores: build_core_library(),
            agents: Mutex::new(BTreeMap::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let inner2 = Arc::clone(&inner);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let inner = Arc::clone(&inner2);
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, inner);
                });
            }
        });
        Ok(ManagementServer {
            inner,
            addr,
            stop,
            handle: Some(handle),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Register a node agent for routed status calls.
    pub fn register_agent(&self, node: NodeId, addr: SocketAddr) {
        self.inner.agents.lock().unwrap().insert(node, addr);
    }

    /// Names of the prebuilt user cores the server can program.
    pub fn core_names(&self) -> Vec<String> {
        self.inner.cores.keys().cloned().collect()
    }

    /// The cluster scheduler behind this server.
    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.inner.sched
    }

    /// The async-job registry behind this server.
    pub fn jobs(&self) -> &Arc<JobRegistry> {
        &self.inner.jobs
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ManagementServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Build the server's core library: one relocatable bitfile per known
/// core (synth report resources, slot-0 frames — retargeted at
/// program time).
fn build_core_library() -> BTreeMap<String, Bitstream> {
    let synth = Synthesizer::new();
    let mut lib = BTreeMap::new();
    let entries: Vec<(&str, CoreKind, usize)> = vec![
        ("matmul16", CoreKind::MatMul { n: 16 }, 256),
        ("matmul16_small", CoreKind::MatMul { n: 16 }, 64),
        ("matmul32", CoreKind::MatMul { n: 32 }, 64),
        ("loopback", CoreKind::Loopback, 256),
        ("saxpy", CoreKind::Saxpy, 256),
        ("checksum", CoreKind::Checksum, 256),
    ];
    for (name, kind, batch) in entries {
        let spec = CoreSpec::named(kind, "xc7vx485t");
        let report = synth.synthesize(&spec);
        let total = report.total_for(1);
        let mut b = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            &kind.name(),
        )
        .resources(total)
        .frames(crate::hls::flow::region_window(0, 1));
        if let Some(a) = spec.artifact(batch) {
            b = b.artifact(&a);
        }
        lib.insert(name.to_string(), b.build());
    }
    lib
}

fn serve_conn(
    mut stream: TcpStream,
    inner: Arc<ServerInner>,
) -> std::io::Result<()> {
    while let Some(frame) = read_frame(&mut stream)? {
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::error(&e),
            Ok(req) => {
                // The RC3E middleware hop (Table I's +69 ms).
                inner.hv.clock.advance(VirtualTime::from_millis_f64(
                    inner.rpc_overhead_ms,
                ));
                let proto = req.proto.unwrap_or(1);
                let result = req.negotiate_proto().and_then(|_| {
                    let ctx = Ctx {
                        inner: &inner,
                        proto,
                    };
                    dispatch(&ctx, &req.method, &req.params)
                });
                respond(proto, req.id, result)
            }
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

// ===================================================== dispatching

/// Per-request handler context.
struct Ctx<'a> {
    inner: &'a Arc<ServerInner>,
    /// Envelope generation of this request (1 = legacy shapes,
    /// ≥ 2 = typed shapes + job handles for long operations).
    proto: u32,
}

type Handler = fn(&Ctx<'_>, &Json) -> Result<Json, ApiError>;

/// The dispatch table: one typed handler per management-server RPC.
const HANDLERS: &[(Method, Handler)] = &[
    (Method::Hello, h_hello),
    (Method::AddUser, h_add_user),
    (Method::Status, h_status),
    (Method::AllocVfpga, h_alloc_vfpga),
    (Method::AllocPhysical, h_alloc_physical),
    (Method::Release, h_release),
    (Method::ProgramCore, h_program_core),
    (Method::Stream, h_stream),
    (Method::ProgramFull, h_program_full),
    (Method::Migrate, h_migrate),
    (Method::Services, h_services),
    (Method::InvokeService, h_invoke_service),
    (Method::Monitor, h_monitor),
    (Method::Workload, h_workload),
    (Method::SchedStatus, h_sched_status),
    (Method::QuotaSet, h_quota_set),
    (Method::QuotaGet, h_quota_get),
    (Method::UsageReport, h_usage_report),
    (Method::Reserve, h_reserve),
    (Method::CancelReservation, h_cancel_reservation),
    (Method::Energy, h_energy),
    (Method::DbDump, h_db_dump),
    (Method::Cores, h_cores),
    (Method::JobStatus, h_job_status),
    (Method::JobWait, h_job_wait),
    (Method::JobCancel, h_job_cancel),
];

/// Whether the management server serves `method` (dispatch-table
/// completeness is asserted by tests against [`Method::ALL`]).
pub fn method_is_served(method: Method) -> bool {
    HANDLERS.iter().any(|(m, _)| *m == method)
}

fn dispatch(
    ctx: &Ctx<'_>,
    method: &str,
    params: &Json,
) -> Result<Json, ApiError> {
    let m = Method::parse(method)
        .ok_or_else(|| ApiError::unknown_method(method))?;
    let handler = HANDLERS
        .iter()
        .find(|(hm, _)| *hm == m)
        .map(|(_, h)| *h)
        .ok_or_else(|| ApiError::unknown_method(method))?;
    handler(ctx, params)
}

// ===================================================== capability auth

/// Protocol ≥ 2 capability check for mutating RPCs: resolve the
/// allocation (dead/foreign → `bad_lease` regardless of token), then
/// require the presented token to own it (`bad_token` when missing,
/// forged or stale). Returns the disarmed lease handle the handler
/// should operate through — its tenant, not the wire `user` field, is
/// the authorized identity. Protocol 1 returns `None` and keeps the
/// honor-system `user` semantics for exactly one version behind.
fn authorize(
    ctx: &Ctx<'_>,
    alloc: AllocationId,
    lease: Option<LeaseToken>,
) -> Result<Option<Lease>, ApiError> {
    if ctx.proto < 2 {
        return Ok(None);
    }
    let grant = ctx.inner.sched.grant(alloc).ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadLease,
            format!("no scheduler grant for {alloc}"),
        )
    })?;
    let token = lease.ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadToken,
            "protocol 2 requires the lease token on mutating calls",
        )
    })?;
    if grant.token != token {
        return Err(ApiError::new(
            ErrorCode::BadToken,
            format!("lease token does not own {alloc}"),
        ));
    }
    // A concurrent release between the grant check and here reads as
    // a stale token, not a server panic.
    ctx.inner
        .sched
        .lease_handle(token)
        .map(Some)
        .ok_or_else(|| {
            ApiError::new(
                ErrorCode::BadToken,
                "lease released mid-request".to_string(),
            )
        })
}

/// Owner gate for `job_*` RPCs on protocol ≥ 2: an owned job only
/// answers to the token that submitted it.
fn authorize_job(
    ctx: &Ctx<'_>,
    owner: Option<LeaseToken>,
    presented: Option<LeaseToken>,
) -> Result<(), ApiError> {
    if ctx.proto < 2 {
        return Ok(());
    }
    match owner {
        Some(t) if presented != Some(t) => Err(ApiError::new(
            ErrorCode::BadToken,
            "job is owned by a different lease token",
        )),
        _ => Ok(()),
    }
}

// ========================================================= handlers

fn h_hello(_ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = HelloRequest::from_json(p)?;
    let chosen = req.negotiate().ok_or_else(|| {
        ApiError::protocol_mismatch(req.proto_min, req.proto_max)
    })?;
    Ok(HelloResponse {
        version: crate::VERSION.to_string(),
        service: "rc3e-management".to_string(),
        proto_min: PROTO_MIN,
        proto_max: PROTO_MAX,
        proto: chosen,
    }
    .to_json())
}

fn h_add_user(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = AddUserRequest::from_json(p)?;
    let user = ctx.inner.hv.add_user(&req.name);
    Ok(AddUserResponse { user }.to_json())
}

fn h_status(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = StatusRequest::from_json(p)?;
    let inner = ctx.inner;
    // Route via the owning node's agent when registered.
    let node = inner.hv.device(req.fpga).map_err(ApiError::from)?.node;
    let agent_addr = inner.agents.lock().unwrap().get(&node).copied();
    let resp = if let Some(addr) = agent_addr {
        let mut agent =
            Client::connect(addr).map_err(ApiError::internal)?;
        agent.agent_status(req.fpga)?
    } else {
        let st = inner
            .hv
            .status_local(req.fpga)
            .map_err(ApiError::from)?;
        StatusResponse::from_status(&st)
    };
    Ok(resp.to_json())
}

fn h_alloc_vfpga(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = AllocVfpgaRequest::from_json(p)?;
    let model = req.model.unwrap_or(ServiceModel::RAaaS);
    if model == ServiceModel::RSaaS {
        return Err(ApiError::bad_request(
            "alloc_vfpga serves vFPGA models; use alloc_physical for \
             RSaaS",
        ));
    }
    let class = req.class.unwrap_or(RequestClass::Interactive);
    let mut areq = AdmissionRequest::new(req.user, model, class);
    if let Some(n) = req.regions {
        areq = areq.gang(n);
    }
    if req.co_located == Some(true) {
        areq = areq.co_located();
    }
    if let Some(b) = &req.board {
        let board = BoardKind::parse(b).ok_or_else(|| {
            ApiError::bad_request(format!("unknown board '{b}'"))
        })?;
        areq = areq.on_board(board);
    }
    let lease = ctx.inner.sched.admit(&areq).map_err(ApiError::from)?;
    let members: Vec<GangMemberBody> = lease
        .placements()
        .iter()
        .map(|pl| GangMemberBody {
            alloc: pl.alloc,
            vfpga: match pl.target {
                crate::sched::GrantTarget::Vfpga(v, _, _) => v,
                crate::sched::GrantTarget::Physical(_, _) => {
                    unreachable!("vFPGA admission")
                }
            },
            fpga: match pl.target {
                crate::sched::GrantTarget::Vfpga(_, f, _)
                | crate::sched::GrantTarget::Physical(f, _) => f,
            },
            node: match pl.target {
                crate::sched::GrantTarget::Vfpga(_, _, n)
                | crate::sched::GrantTarget::Physical(_, n) => n,
            },
        })
        .collect();
    let primary = members.first().cloned().ok_or_else(|| {
        ApiError::internal("admitted lease has no members")
    })?;
    let resp = AllocVfpgaResponse {
        alloc: primary.alloc,
        vfpga: primary.vfpga,
        fpga: primary.fpga,
        node: primary.node,
        wait_ms: lease.wait().as_millis_f64(),
        lease: lease.token(),
        members,
    };
    // Disarm: the lease stays live server-side, owned by whoever
    // holds the token.
    let _token = lease.into_token();
    Ok(resp.to_json())
}

fn h_alloc_physical(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = AllocPhysicalRequest::from_json(p)?;
    let lease = ctx
        .inner
        .sched
        .admit(&AdmissionRequest::physical(
            req.user,
            RequestClass::Interactive,
        ))
        .map_err(ApiError::from)?;
    let resp = AllocPhysicalResponse {
        alloc: lease.alloc(),
        fpga: lease.fpga().ok_or_else(|| {
            ApiError::internal("fresh physical lease has no placement")
        })?,
        node: lease.node().ok_or_else(|| {
            ApiError::internal("fresh physical lease has no placement")
        })?,
        lease: lease.token(),
    };
    let _token = lease.into_token();
    Ok(resp.to_json())
}

fn h_release(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = ReleaseRequest::from_json(p)?;
    if let Some(handle) = authorize(ctx, req.alloc, req.lease)? {
        // Protocol ≥ 2: the capability releases the *whole* lease
        // (every gang member), like Lease::release everywhere else.
        handle.release().map_err(ApiError::from)?;
        return Ok(ReleaseResponse { released: true }.to_json());
    }
    // Protocol 1 (one version behind): by-allocation release.
    // Scheduler-tracked leases release through the scheduler (quota
    // credit + queue pump); anything allocated out of band falls back
    // to the hypervisor.
    match ctx.inner.sched.release(req.alloc) {
        Ok(()) => {}
        Err(SchedError::UnknownGrant(_)) => ctx
            .inner
            .hv
            .release(req.alloc)
            .map_err(ApiError::from)?,
        Err(e) => return Err(ApiError::from(e)),
    }
    Ok(ReleaseResponse { released: true }.to_json())
}

fn h_program_core(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let mut req = ProgramCoreRequest::from_json(p)?;
    if let Some(handle) = authorize(ctx, req.alloc, req.lease)? {
        // The token's tenant is the authorized identity — the wire
        // `user` field is no longer trusted on protocol ≥ 2.
        req.user = handle.tenant();
    }
    let inner = ctx.inner;
    let bitfile = inner.cores.get(&req.core).ok_or_else(|| {
        ApiError::new(
            ErrorCode::UnknownCore,
            format!("unknown core '{}'", req.core),
        )
    })?;
    // Retarget + PR under one region pin: a relocation cannot slip
    // between placement resolution and programming.
    let d = inner
        .hv
        .program_retargeted(req.alloc, req.user, bitfile)
        .map_err(ApiError::from)?;
    Ok(ProgramCoreResponse {
        programmed: req.core,
        pr_ms: d.as_millis_f64(),
    }
    .to_json())
}

fn h_stream(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let mut req = StreamRequest::from_json(p)?;
    if ctx.proto >= 2 {
        let handle = authorize(ctx, req.alloc, req.lease)?
            .expect("authorize returns a handle on proto >= 2");
        req.user = handle.tenant();
        let owner = req.lease;
        let inner = Arc::clone(ctx.inner);
        let now_ns = ctx.inner.hv.clock.now().0;
        let job = Arc::clone(&ctx.inner.jobs).submit(
            Method::Stream.name(),
            now_ns,
            owner,
            move || run_stream(&inner, &req),
        );
        return Ok(JobSubmitResponse { job, lease: owner }.to_json());
    }
    run_stream(ctx.inner, &req)
}

fn h_program_full(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let mut req = ProgramFullRequest::from_json(p)?;
    if ctx.proto >= 2 {
        let handle = authorize(ctx, req.alloc, req.lease)?
            .expect("authorize returns a handle on proto >= 2");
        req.user = handle.tenant();
        let owner = req.lease;
        let inner = Arc::clone(ctx.inner);
        let now_ns = ctx.inner.hv.clock.now().0;
        let job = Arc::clone(&ctx.inner.jobs).submit(
            Method::ProgramFull.name(),
            now_ns,
            owner,
            move || run_program_full(&inner, &req),
        );
        return Ok(JobSubmitResponse { job, lease: owner }.to_json());
    }
    run_program_full(ctx.inner, &req)
}

fn h_invoke_service(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = InvokeServiceRequest::from_json(p)?;
    if ctx.proto >= 2 {
        // No lease is involved (BAaaS allocates internally); mint a
        // job-scoped owner token so the job handle is still a
        // capability, not an enumerable id anyone can cancel.
        let owner = LeaseToken::mint();
        let inner = Arc::clone(ctx.inner);
        let now_ns = ctx.inner.hv.clock.now().0;
        let job = Arc::clone(&ctx.inner.jobs).submit(
            Method::InvokeService.name(),
            now_ns,
            Some(owner),
            move || run_invoke_service(&inner, &req),
        );
        return Ok(JobSubmitResponse {
            job,
            lease: Some(owner),
        }
        .to_json());
    }
    run_invoke_service(ctx.inner, &req)
}

fn h_migrate(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let mut req = MigrateRequest::from_json(p)?;
    if let Some(handle) = authorize(ctx, req.alloc, req.lease)? {
        req.user = handle.tenant();
    }
    // Default target selection is model-aware (see
    // hypervisor::migration), so the relocated lease stays within the
    // per-device model policy.
    let report = ctx
        .inner
        .hv
        .migrate_vfpga(req.alloc, req.user, None)
        .map_err(ApiError::from)?;
    // Keep the scheduler's view of the lease current so preemption
    // victim selection and sched_status stay accurate.
    ctx.inner.sched.note_migration(req.alloc, report.to);
    Ok(MigrateResponse {
        from: report.from,
        to: report.to,
        cross_device: report.moved_across_devices,
        downtime_ms: report.downtime.as_millis_f64(),
    }
    .to_json())
}

fn h_services(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = ServicesRequest::from_json(p)?;
    let resp = ServicesResponse {
        services: ctx.inner.hv.service_names(),
    };
    Ok(if ctx.proto >= 2 {
        resp.to_json()
    } else {
        resp.to_legacy_json()
    })
}

fn h_cores(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = CoresRequest::from_json(p)?;
    let resp = CoresResponse {
        cores: ctx.inner.cores.keys().cloned().collect(),
    };
    Ok(if ctx.proto >= 2 {
        resp.to_json()
    } else {
        resp.to_legacy_json()
    })
}

fn h_monitor(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = MonitorRequest::from_json(p)?;
    let hv = &ctx.inner.hv;
    // One monitoring sweep over every device + report, plus the
    // scheduler's admission telemetry (ROADMAP item: expose the
    // `sched.wait` histogram and queue-depth gauge over the wire) and
    // the region-lifecycle telemetry (per-state occupancy gauges,
    // quiesce-wait histogram, raced counter).
    let mut mon = crate::hypervisor::Monitor::new();
    mon.sample_all(hv);
    hv.refresh_region_gauges();
    let wait = hv.metrics.histogram("sched.wait");
    let quiesce_wait =
        hv.metrics.histogram("sched.preempt.quiesce_wait");
    let state_gauge =
        |name: &str| hv.metrics.gauge(&format!("region.state.{name}")).get();
    Ok(MonitorResponse {
        devices: mon.to_json(),
        cloud_utilization: mon.cloud_utilization(),
        sched: SchedTelemetry {
            queue_depth: hv.metrics.gauge("sched.queue.depth").get(),
            active_grants: hv
                .metrics
                .gauge("sched.active_grants")
                .get(),
            wait: WaitStats::from_histogram(&wait),
            quiesce_wait: WaitStats::from_histogram(&quiesce_wait),
            preempt_raced: hv
                .metrics
                .counter("sched.preempt.raced")
                .get(),
            lifecycle: LifecycleOccupancy {
                free: state_gauge("free"),
                reserved: state_gauge("reserved"),
                programming: state_gauge("programming"),
                active: state_gauge("active"),
                draining: state_gauge("draining"),
                migrating: state_gauge("migrating"),
            },
        },
    }
    .to_json())
}

fn h_workload(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = WorkloadRequest::from_json(p)?;
    // Run a synthetic session workload (operator tooling / capacity
    // planning).
    let w = crate::hypervisor::CloudWorkload {
        arrival_rate: req.rate.unwrap_or(0.05),
        mean_hold_s: req.hold_s.unwrap_or(120.0),
        sessions: req.sessions.unwrap_or(40) as usize,
        seed: req.seed.unwrap_or(0x10AD),
    };
    let report = crate::hypervisor::workload::run(&ctx.inner.hv, &w)
        .map_err(|e| ApiError::internal(e.to_string()))?;
    Ok(WorkloadResponse {
        served: report.served as u64,
        rejected: report.rejected as u64,
        admission_rate: report.admission_rate(),
        mean_setup_ms: report.mean_setup_ms,
        mean_utilization: report.mean_utilization,
        makespan_s: report.makespan.as_secs_f64(),
        energy_j: report.energy_j,
    }
    .to_json())
}

fn h_sched_status(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = SchedStatusRequest::from_json(p)?;
    Ok(SchedStatusResponse {
        status: ctx.inner.sched.status_json(),
    }
    .to_json())
}

fn h_quota_set(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = QuotaSetRequest::from_json(p)?;
    // Absent fields keep their current values; `max_vfpgas: 0`
    // restores an unlimited cap and a negative `budget_s` clears the
    // budget (the JSON layer cannot distinguish null from absent).
    // The merge runs atomically under the scheduler's lock so
    // concurrent partial updates cannot lose each other's fields.
    let quota = ctx.inner.sched.update_quota(req.user, |q| {
        match req.max_vfpgas {
            Some(0) => q.max_concurrent = u64::MAX,
            Some(n) => q.max_concurrent = n,
            None => {}
        }
        match req.budget_s {
            Some(b) if b < 0.0 => q.device_seconds_budget = None,
            Some(b) => q.device_seconds_budget = Some(b),
            None => {}
        }
        if let Some(w) = req.weight {
            q.weight = w.max(1);
        }
    });
    Ok(QuotaResponse::from_quota(
        req.user,
        &quota,
        ctx.inner.sched.in_use(req.user),
    )
    .to_json())
}

fn h_quota_get(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = QuotaGetRequest::from_json(p)?;
    let quota = ctx.inner.sched.quota(req.user);
    Ok(QuotaResponse::from_quota(
        req.user,
        &quota,
        ctx.inner.sched.in_use(req.user),
    )
    .to_json())
}

fn h_usage_report(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = UsageReportRequest::from_json(p)?;
    Ok(UsageReportResponse {
        tenants: ctx.inner.sched.usage_json(),
        table: ctx.inner.sched.usage_report(),
    }
    .to_json())
}

fn h_reserve(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = ReserveRequest::from_json(p)?;
    let start_s = req
        .start_s
        .unwrap_or_else(|| ctx.inner.hv.clock.now().as_secs_f64());
    let duration_s = req.duration_s.unwrap_or(3600.0);
    let reservation = ctx.inner.sched.reserve(
        req.user,
        req.regions,
        req.model,
        VirtualTime::from_secs_f64(start_s),
        VirtualTime::from_secs_f64(duration_s),
    );
    Ok(ReserveResponse { reservation }.to_json())
}

fn h_cancel_reservation(
    ctx: &Ctx<'_>,
    p: &Json,
) -> Result<Json, ApiError> {
    let req = CancelReservationRequest::from_json(p)?;
    ctx.inner
        .sched
        .cancel_reservation(req.reservation)
        .map_err(ApiError::from)?;
    Ok(CancelReservationResponse { cancelled: true }.to_json())
}

fn h_energy(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = EnergyRequest::from_json(p)?;
    Ok(EnergyResponse {
        joules: ctx.inner.hv.total_energy_joules(),
        power_w: ctx.inner.hv.total_power_w(),
    }
    .to_json())
}

fn h_db_dump(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = DbDumpRequest::from_json(p)?;
    Ok(DbDumpResponse {
        db: ctx.inner.hv.db.lock().unwrap().to_json(),
    }
    .to_json())
}

fn h_job_status(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = JobStatusRequest::from_json(p)?;
    let rec = ctx.inner.jobs.status(req.job)?;
    authorize_job(ctx, rec.owner, req.lease)?;
    Ok(rec.to_body().to_json())
}

fn h_job_wait(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = JobWaitRequest::from_json(p)?;
    // Gate on ownership *before* blocking — a forged token must not
    // be able to park threads on someone else's job.
    let rec = ctx.inner.jobs.status(req.job)?;
    authorize_job(ctx, rec.owner, req.lease)?;
    // Cap below the client library's 120 s socket read timeout: a
    // server-side wait that outlives the client's read would leave a
    // stale frame on the connection and desynchronize every later
    // response. Clients long-poll by retrying on `timeout` instead
    // (see Client::job_wait_done).
    let timeout_s = req
        .timeout_s
        .unwrap_or(DEFAULT_WAIT_S)
        .clamp(0.01, MAX_WAIT_S);
    let rec = ctx
        .inner
        .jobs
        .wait(req.job, Duration::from_secs_f64(timeout_s))?;
    Ok(rec.to_body().to_json())
}

fn h_job_cancel(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = JobCancelRequest::from_json(p)?;
    let rec = ctx.inner.jobs.status(req.job)?;
    authorize_job(ctx, rec.owner, req.lease)?;
    Ok(ctx.inner.jobs.cancel(req.job)?.to_body().to_json())
}

// ====================================== long-running operation bodies
//
// Shared by the synchronous protocol-1 path and the protocol-2 job
// workers, so `submit + job_wait` reproduces the old blocking
// behavior exactly.

fn stream_config_for(
    core: &str,
    mults: u64,
) -> Result<StreamConfig, ApiError> {
    match core {
        "matmul16" => Ok(StreamConfig::matmul16(mults)),
        "matmul32" => Ok(StreamConfig::matmul32(mults)),
        c => Err(ApiError::new(
            ErrorCode::UnknownCore,
            format!("no stream profile for core '{c}'"),
        )),
    }
}

fn run_stream(
    inner: &ServerInner,
    req: &StreamRequest,
) -> Result<Json, ApiError> {
    let cfg = stream_config_for(&req.core, req.mults)?;
    // Recover the lease handle from the grant (v1 callers present no
    // token, but the grant knows its own) so the session-open +
    // streaming body lives in exactly one place: Lease::stream. The
    // handle resolves placement at run time — a migration between
    // submit and run streams through the new device.
    let grant = inner.sched.grant(req.alloc).ok_or_else(|| {
        ApiError::from(HypervisorError::BadAllocation(req.alloc))
    })?;
    if grant.user != req.user {
        return Err(ApiError::from(HypervisorError::BadAllocation(
            req.alloc,
        )));
    }
    let handle = inner.sched.lease_handle(grant.token).ok_or_else(|| {
        ApiError::from(HypervisorError::BadAllocation(req.alloc))
    })?;
    // Stream the *requested* member (gang leases share one token).
    let idx = handle
        .members()
        .iter()
        .position(|a| *a == req.alloc)
        .unwrap_or(0);
    let out = handle.stream_member(idx, &cfg).map_err(ApiError::from)?;
    Ok(StreamOutcomeBody::from_outcome(&out).to_json())
}

fn run_program_full(
    inner: &ServerInner,
    req: &ProgramFullRequest,
) -> Result<Json, ApiError> {
    // RSaaS: write a full user bitstream to an exclusively held
    // device (server builds the synthetic image; a real deployment
    // would receive an upload).
    let name = req
        .name
        .clone()
        .unwrap_or_else(|| "user_design".to_string());
    let fpga = {
        let db = inner.hv.db.lock().unwrap();
        db.allocations
            .get(&req.alloc)
            .and_then(|a| match a.kind {
                AllocKind::Physical(f) | AllocKind::Vm(_, f) => Some(f),
                _ => None,
            })
            .ok_or_else(|| {
                ApiError::new(
                    ErrorCode::BadLease,
                    format!("allocation {} is not physical", req.alloc),
                )
            })?
    };
    let part = inner
        .hv
        .device(fpga)
        .map_err(ApiError::from)?
        .fpga
        .lock()
        .unwrap()
        .board
        .part;
    let bs =
        crate::bitstream::BitstreamBuilder::full(part, &name).build();
    let d = inner
        .hv
        .program_full(req.alloc, req.user, &bs)
        .map_err(ApiError::from)?;
    Ok(ProgramFullResponse {
        programmed: name,
        config_s: d.as_secs_f64(),
    }
    .to_json())
}

fn run_invoke_service(
    inner: &ServerInner,
    req: &InvokeServiceRequest,
) -> Result<Json, ApiError> {
    let core = if req.service.contains("32") {
        "matmul32"
    } else {
        "matmul16"
    };
    let cfg = stream_config_for(core, req.mults)?;
    let svc = crate::service::BaaasService::with_scheduler(Arc::clone(
        &inner.sched,
    ));
    let out = svc
        .invoke(req.user, &req.service, &cfg)
        .map_err(ApiError::from)?;
    Ok(StreamOutcomeBody::from_outcome(&out).to_json())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn setup() -> (ManagementServer, Client, Arc<Hypervisor>) {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
        let client = Client::connect(server.addr()).unwrap();
        (server, client, hv)
    }

    #[test]
    fn dispatch_table_covers_every_management_method() {
        for m in Method::ALL {
            assert_eq!(
                method_is_served(m),
                !m.is_agent(),
                "dispatch entry mismatch for {}",
                m.name()
            );
        }
    }

    #[test]
    fn hello_and_cores() {
        let (_s, mut c, _hv) = setup();
        let body = c.call("hello", Json::obj(vec![])).unwrap();
        assert_eq!(body.get("version").as_str(), Some(crate::VERSION));
        // The server advertises its protocol window.
        assert_eq!(
            body.get("proto_max").as_u64(),
            Some(u64::from(PROTO_MAX))
        );
        let cores = c.call("cores", Json::obj(vec![])).unwrap();
        assert!(cores
            .as_arr()
            .unwrap()
            .iter()
            .any(|c| c.as_str() == Some("matmul16")));
    }

    #[test]
    fn status_over_rc3e_costs_80ms() {
        let (_s, mut c, hv) = setup();
        let t0 = hv.clock.now();
        let body = c
            .call(
                "status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!(
            (ms - crate::paper::STATUS_RC3E_MS).abs() < 0.5,
            "status over RC3E took {ms} ms"
        );
        assert_eq!(body.get("regions_total").as_u64(), Some(4));
    }

    #[test]
    fn status_routes_through_registered_agent() {
        let (s, mut c, hv) = setup();
        let agent = super::super::agent::NodeAgent::spawn(
            Arc::clone(&hv),
            NodeId(0),
            None,
        )
        .unwrap();
        s.register_agent(NodeId(0), agent.addr());
        let t0 = hv.clock.now();
        let body = c
            .call(
                "status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        assert_eq!(body.get("board").as_str(), Some("vc707"));
        // Same virtual cost as the unrouted path (Table I: local vs
        // remote node over RC3E are both 80 ms).
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!((ms - 80.0).abs() < 0.5, "{ms}");
    }

    #[test]
    fn full_lease_cycle_over_rpc() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("cli"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let alloc = lease.get("alloc").as_str().unwrap().to_string();
        let prog = c
            .call(
                "program_core",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                ]),
            )
            .unwrap();
        // PR over RC3E ≈ 732 + 111 (orchestration); the RPC hop is
        // charged before dispatch.
        let pr_ms = prog.get("pr_ms").as_f64().unwrap();
        assert!((pr_ms - 843.0).abs() < 1.0, "{pr_ms}");
        c.call(
            "release",
            Json::obj(vec![("alloc", Json::from(alloc.as_str()))]),
        )
        .unwrap();
    }

    #[test]
    fn stream_over_rpc_returns_outcome() {
        if !crate::testing::artifacts_available(
            "middleware::stream_over_rpc_returns_outcome",
        ) {
            return;
        }
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("u"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let alloc = lease.get("alloc").as_str().unwrap().to_string();
        c.call(
            "program_core",
            Json::obj(vec![
                ("user", Json::from(user.as_str())),
                ("alloc", Json::from(alloc.as_str())),
                ("core", Json::from("matmul16")),
            ]),
        )
        .unwrap();
        // A v1 (proto-less) stream request stays synchronous.
        let out = c
            .call(
                "stream",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                    ("mults", Json::from(512u64)),
                ]),
            )
            .unwrap();
        assert_eq!(out.get("validation_failures").as_u64(), Some(0));
        assert!(out.get("virtual_mbps").as_f64().unwrap() > 400.0);
    }

    #[test]
    fn errors_are_application_level() {
        let (_s, mut c, _hv) = setup();
        // Unknown method.
        assert!(c.call("reboot_world", Json::obj(vec![])).is_err());
        // Bad params.
        assert!(c
            .call("status", Json::obj(vec![("fpga", Json::from("x"))]))
            .is_err());
        // Connection survives both errors.
        assert!(c.call("hello", Json::obj(vec![])).is_ok());
    }

    #[test]
    fn db_dump_is_valid_json_db() {
        let (_s, mut c, _hv) = setup();
        let dump = c.call("db_dump", Json::obj(vec![])).unwrap();
        let db = crate::hypervisor::DeviceDb::from_json(&dump).unwrap();
        assert_eq!(db.devices.len(), 4);
    }

    #[test]
    fn quota_rpcs_roundtrip_and_enforce() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("q"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let set = c
            .call(
                "quota_set",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("max_vfpgas", Json::from(1u64)),
                    ("weight", Json::from(3u64)),
                ]),
            )
            .unwrap();
        assert_eq!(set.get("max_vfpgas").as_u64(), Some(1));
        let got = c
            .call(
                "quota_get",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        assert_eq!(got.get("weight").as_u64(), Some(3));
        // First lease fits the quota; the second is denied.
        c.call(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from(user.as_str()))]),
        )
        .unwrap();
        let err = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap_err();
        assert!(err.contains("quota"), "{err}");
    }

    #[test]
    fn sched_status_and_usage_rpcs() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("u"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let status =
            c.call("sched_status", Json::obj(vec![])).unwrap();
        assert_eq!(status.get("active_grants").as_u64(), Some(1));
        assert_eq!(status.get("queue_depth").as_u64(), Some(0));
        c.call(
            "release",
            Json::obj(vec![(
                "alloc",
                Json::from(lease.get("alloc").as_str().unwrap()),
            )]),
        )
        .unwrap();
        let usage = c.call("usage_report", Json::obj(vec![])).unwrap();
        let tenants = usage.get("tenants").as_arr().unwrap();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].get("released").as_u64(), Some(1));
        assert!(usage
            .get("table")
            .as_str()
            .unwrap()
            .contains("tenant"));
    }

    #[test]
    fn reservation_rpcs_withhold_capacity() {
        let (_s, mut c, _hv) = setup();
        let mk_user = |c: &mut Client, name: &str| {
            c.call(
                "add_user",
                Json::obj(vec![("name", Json::from(name))]),
            )
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string()
        };
        let holder = mk_user(&mut c, "holder");
        let other = mk_user(&mut c, "other");
        // Reserve the whole 16-region testbed for the holder.
        let r = c
            .call(
                "reserve",
                Json::obj(vec![
                    ("user", Json::from(holder.as_str())),
                    ("regions", Json::from(16u64)),
                    ("duration_s", Json::from(10_000.0)),
                ]),
            )
            .unwrap();
        let err = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(other.as_str()))]),
            )
            .unwrap_err();
        assert!(err.contains("no capacity"), "{err}");
        c.call(
            "cancel_reservation",
            Json::obj(vec![(
                "reservation",
                Json::from(r.get("reservation").as_str().unwrap()),
            )]),
        )
        .unwrap();
        assert!(c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(other.as_str()))]),
            )
            .is_ok());
    }

    #[test]
    fn monitor_exposes_sched_telemetry() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("m"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        c.call(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from(user.as_str()))]),
        )
        .unwrap();
        let mon = c.call("monitor", Json::obj(vec![])).unwrap();
        let sched = mon.get("sched");
        assert_eq!(sched.get("active_grants").as_u64(), Some(1));
        assert_eq!(sched.get("queue_depth").as_u64(), Some(0));
        // The grant above recorded one admission wait sample.
        assert!(sched.get("wait").get("count").as_u64().unwrap() >= 1);
        // Lifecycle telemetry: the allocated-but-unprogrammed region
        // reads Reserved; nothing drains or migrates at rest; the
        // defense-in-depth raced counter is 0.
        let lifecycle = sched.get("lifecycle");
        assert_eq!(lifecycle.get("reserved").as_u64(), Some(1));
        assert_eq!(lifecycle.get("draining").as_u64(), Some(0));
        assert_eq!(lifecycle.get("migrating").as_u64(), Some(0));
        assert_eq!(sched.get("preempt_raced").as_u64(), Some(0));
        assert!(sched
            .get("quiesce_wait")
            .get("count")
            .as_u64()
            .is_some());
        // The same states are visible per device in `status`.
        let st = c
            .call(
                "status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        assert_eq!(st.get("regions_draining").as_u64(), Some(0));
        assert_eq!(st.get("regions_migrating").as_u64(), Some(0));
    }
}
