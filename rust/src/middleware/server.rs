//! The management-node server: the middleware entry point users talk
//! to (the CLI connects here).
//!
//! Every incoming request charges the cluster's RPC overhead to the
//! virtual clock (Table I: the RC3E hop turns an 11 ms local status
//! call into 80 ms) and then dispatches into the hypervisor. Device
//! status is routed through the owning node's [`super::NodeAgent`]
//! when one is registered — the management→node Ethernet hop.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::client::Client;
use super::proto::{read_frame, write_frame, Request, Response};
use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hls::synth::{CoreKind, CoreSpec, Synthesizer};
use crate::hypervisor::Hypervisor;
use crate::rc2f::stream::StreamConfig;
use crate::sched::{RequestClass, SchedError, Scheduler, TenantQuota};
use crate::util::clock::VirtualTime;
use crate::util::ids::{AllocationId, FpgaId, NodeId, ReservationId, UserId};
use crate::util::json::Json;

/// The management server (owns its accept thread).
pub struct ManagementServer {
    inner: Arc<ServerInner>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

struct ServerInner {
    hv: Arc<Hypervisor>,
    /// The cluster scheduler — every allocation RPC admits through it.
    sched: Arc<Scheduler>,
    rpc_overhead_ms: f64,
    /// Prebuilt relocatable user-core bitfiles ("the user uploads a
    /// bitfile" — kept server-side so the CLI can reference cores by
    /// name).
    cores: BTreeMap<String, Bitstream>,
    /// node → agent address for routed device ops.
    agents: Mutex<BTreeMap<NodeId, SocketAddr>>,
}

impl ManagementServer {
    /// Spawn on an ephemeral loopback port.
    pub fn spawn(
        hv: Arc<Hypervisor>,
        rpc_overhead_ms: f64,
    ) -> std::io::Result<ManagementServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let sched = Scheduler::new(Arc::clone(&hv));
        let inner = Arc::new(ServerInner {
            hv,
            sched,
            rpc_overhead_ms,
            cores: build_core_library(),
            agents: Mutex::new(BTreeMap::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let inner2 = Arc::clone(&inner);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let inner = Arc::clone(&inner2);
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, inner);
                });
            }
        });
        Ok(ManagementServer {
            inner,
            addr,
            stop,
            handle: Some(handle),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Register a node agent for routed status calls.
    pub fn register_agent(&self, node: NodeId, addr: SocketAddr) {
        self.inner.agents.lock().unwrap().insert(node, addr);
    }

    /// Names of the prebuilt user cores the server can program.
    pub fn core_names(&self) -> Vec<String> {
        self.inner.cores.keys().cloned().collect()
    }

    /// The cluster scheduler behind this server.
    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.inner.sched
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ManagementServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Build the server's core library: one relocatable bitfile per known
/// core (synth report resources, slot-0 frames — retargeted at
/// program time).
fn build_core_library() -> BTreeMap<String, Bitstream> {
    let synth = Synthesizer::new();
    let mut lib = BTreeMap::new();
    let entries: Vec<(&str, CoreKind, usize)> = vec![
        ("matmul16", CoreKind::MatMul { n: 16 }, 256),
        ("matmul16_small", CoreKind::MatMul { n: 16 }, 64),
        ("matmul32", CoreKind::MatMul { n: 32 }, 64),
        ("loopback", CoreKind::Loopback, 256),
        ("saxpy", CoreKind::Saxpy, 256),
        ("checksum", CoreKind::Checksum, 256),
    ];
    for (name, kind, batch) in entries {
        let spec = CoreSpec::named(kind, "xc7vx485t");
        let report = synth.synthesize(&spec);
        let total = report.total_for(1);
        let mut b = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            &kind.name(),
        )
        .resources(total)
        .frames(crate::hls::flow::region_window(0, 1));
        if let Some(a) = spec.artifact(batch) {
            b = b.artifact(&a);
        }
        lib.insert(name.to_string(), b.build());
    }
    lib
}

fn serve_conn(
    mut stream: TcpStream,
    inner: Arc<ServerInner>,
) -> std::io::Result<()> {
    while let Some(frame) = read_frame(&mut stream)? {
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::error(&e),
            Ok(req) => {
                // The RC3E middleware hop (Table I's +69 ms).
                inner.hv.clock.advance(VirtualTime::from_millis_f64(
                    inner.rpc_overhead_ms,
                ));
                dispatch(&inner, &req)
                    .unwrap_or_else(|e| Response::error(&e))
            }
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

fn parse_user(params: &Json) -> Result<UserId, String> {
    UserId::parse(params.str_field("user")?)
        .ok_or_else(|| "bad user id".to_string())
}

fn parse_alloc(params: &Json) -> Result<AllocationId, String> {
    AllocationId::parse(params.str_field("alloc")?)
        .ok_or_else(|| "bad alloc id".to_string())
}

fn stream_config_for(
    core: &str,
    mults: u64,
) -> Result<StreamConfig, String> {
    match core {
        "matmul16" => Ok(StreamConfig::matmul16(mults)),
        "matmul32" => Ok(StreamConfig::matmul32(mults)),
        c => Err(format!("no stream profile for core '{c}'")),
    }
}

fn quota_json(
    user: UserId,
    quota: &TenantQuota,
    in_use: u64,
) -> Json {
    // 0 = unlimited, mirroring quota_set's convention (u64::MAX would
    // lose precision through the f64-backed Json number anyway).
    let max_vfpgas = if quota.max_concurrent == u64::MAX {
        0
    } else {
        quota.max_concurrent
    };
    Json::obj(vec![
        ("user", Json::from(user.to_string())),
        ("max_vfpgas", Json::from(max_vfpgas)),
        (
            "budget_s",
            match quota.device_seconds_budget {
                Some(b) => Json::from(b),
                None => Json::Null,
            },
        ),
        ("weight", Json::from(quota.weight)),
        ("in_use", Json::from(in_use)),
    ])
}

fn outcome_json(out: &crate::rc2f::stream::StreamOutcome) -> Json {
    Json::obj(vec![
        ("artifact", Json::from(out.artifact.as_str())),
        ("mults", Json::from(out.mults)),
        ("input_bytes", Json::from(out.input_bytes)),
        ("output_bytes", Json::from(out.output_bytes)),
        (
            "virtual_stream_s",
            Json::from(out.virtual_stream.as_secs_f64()),
        ),
        (
            "virtual_total_s",
            Json::from(out.virtual_total.as_secs_f64()),
        ),
        ("virtual_mbps", Json::from(out.virtual_mbps())),
        ("wall_s", Json::from(out.wall_secs)),
        ("wall_mbps", Json::from(out.wall_mbps())),
        ("checksum", Json::from(out.checksum)),
        (
            "validation_failures",
            Json::from(out.validation_failures),
        ),
    ])
}

fn dispatch(inner: &ServerInner, req: &Request) -> Result<Response, String> {
    let hv = &inner.hv;
    let p = &req.params;
    let ok = |j: Json| Ok(Response::success(j));
    match req.method.as_str() {
        "hello" => ok(Json::obj(vec![
            ("version", Json::from(crate::VERSION)),
            ("service", Json::from("rc3e-management")),
        ])),
        "add_user" => {
            let name = p.str_field("name")?;
            let id = hv.add_user(name);
            ok(Json::obj(vec![("user", Json::from(id.to_string()))]))
        }
        "status" => {
            let fpga = FpgaId::parse(p.str_field("fpga")?)
                .ok_or("bad fpga id")?;
            // Route via the owning node's agent when registered.
            let node = hv
                .device(fpga)
                .map_err(|e| e.to_string())?
                .node;
            let agent_addr =
                inner.agents.lock().unwrap().get(&node).copied();
            if let Some(addr) = agent_addr {
                let mut agent = Client::connect(addr)?;
                let body = agent.call(
                    "agent.status",
                    Json::obj(vec![(
                        "fpga",
                        Json::from(fpga.to_string()),
                    )]),
                )?;
                return Ok(Response::success(body));
            }
            let st = hv.status_local(fpga).map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("fpga", Json::from(st.fpga.to_string())),
                ("board", Json::from(st.board)),
                ("regions_total", Json::from(st.regions_total)),
                (
                    "regions_configured",
                    Json::from(st.regions_configured),
                ),
                ("regions_clocked", Json::from(st.regions_clocked)),
                ("power_w", Json::from(st.power_w)),
            ]))
        }
        "alloc_vfpga" => {
            let user = parse_user(p)?;
            // Absent params default; present-but-unparsable ones are
            // errors (a typo must not silently escalate a batch
            // request to interactive, which could preempt someone).
            let model = match p.get("model").as_str() {
                Some(s) => ServiceModel::parse(s)
                    .ok_or_else(|| format!("unknown model '{s}'"))?,
                None => ServiceModel::RAaaS,
            };
            let class = match p.get("class").as_str() {
                Some(s) => RequestClass::parse(s)
                    .ok_or_else(|| format!("unknown class '{s}'"))?,
                None => RequestClass::Interactive,
            };
            let grant = inner
                .sched
                .acquire_vfpga(user, model, class)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("alloc", Json::from(grant.alloc.to_string())),
                (
                    "vfpga",
                    Json::from(
                        grant.vfpga().expect("vfpga grant").to_string(),
                    ),
                ),
                ("fpga", Json::from(grant.fpga().to_string())),
                ("node", Json::from(grant.node().to_string())),
                ("wait_ms", Json::from(grant.wait.as_millis_f64())),
            ]))
        }
        "alloc_physical" => {
            let user = parse_user(p)?;
            let grant = inner
                .sched
                .acquire_physical(user, None, RequestClass::Interactive)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("alloc", Json::from(grant.alloc.to_string())),
                ("fpga", Json::from(grant.fpga().to_string())),
                ("node", Json::from(grant.node().to_string())),
            ]))
        }
        "release" => {
            let alloc = parse_alloc(p)?;
            // Scheduler-tracked leases release through the scheduler
            // (quota credit + queue pump); anything allocated out of
            // band falls back to the hypervisor.
            match inner.sched.release(alloc) {
                Ok(()) => {}
                Err(SchedError::UnknownGrant(_)) => {
                    hv.release(alloc).map_err(|e| e.to_string())?
                }
                Err(e) => return Err(e.to_string()),
            }
            ok(Json::obj(vec![("released", Json::from(true))]))
        }
        "program_core" => {
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            let core = p.str_field("core")?;
            let bitfile = inner
                .cores
                .get(core)
                .ok_or_else(|| format!("unknown core '{core}'"))?;
            let vfpga = hv
                .check_vfpga_lease(alloc, user)
                .map_err(|e| e.to_string())?;
            let placed = hv
                .retarget_for(vfpga, bitfile)
                .map_err(|e| e.to_string())?;
            let d = hv
                .program_vfpga(alloc, user, &placed)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("programmed", Json::from(core)),
                ("pr_ms", Json::from(d.as_millis_f64())),
            ]))
        }
        "stream" => {
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            let core = p.str_field("core")?;
            let mults = p.u64_field("mults")?;
            let cfg = stream_config_for(core, mults)?;
            let svc = crate::service::RaaasService::with_scheduler(
                Arc::clone(&inner.sched),
            );
            let out = svc
                .stream(alloc, user, &cfg)
                .map_err(|e| e.to_string())?;
            ok(outcome_json(&out))
        }
        "program_full" => {
            // RSaaS: write a full user bitstream to an exclusively
            // held device (server builds the synthetic image; a real
            // deployment would receive an upload).
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            let name = p.get("name").as_str().unwrap_or("user_design");
            let part = {
                let db = hv.db.lock().unwrap();
                let fpga = db
                    .allocations
                    .get(&alloc)
                    .and_then(|a| match a.kind {
                        crate::hypervisor::AllocKind::Physical(f)
                        | crate::hypervisor::AllocKind::Vm(_, f) => Some(f),
                        _ => None,
                    })
                    .ok_or("allocation is not physical")?;
                drop(db);
                hv.device(fpga).map_err(|e| e.to_string())?.fpga
                    .lock()
                    .unwrap()
                    .board
                    .part
            };
            let bs = crate::bitstream::BitstreamBuilder::full(part, name)
                .build();
            let d = hv
                .program_full(alloc, user, &bs)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("programmed", Json::from(name)),
                ("config_s", Json::from(d.as_secs_f64())),
            ]))
        }
        "migrate" => {
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            // Default target selection is model-aware (see
            // hypervisor::migration), so the relocated lease stays
            // within the per-device model policy.
            let report = hv
                .migrate_vfpga(alloc, user, None)
                .map_err(|e| e.to_string())?;
            // Keep the scheduler's view of the lease current so
            // preemption victim selection and sched_status stay
            // accurate.
            inner.sched.note_migration(alloc, report.to);
            ok(Json::obj(vec![
                ("from", Json::from(report.from.to_string())),
                ("to", Json::from(report.to.to_string())),
                (
                    "cross_device",
                    Json::from(report.moved_across_devices),
                ),
                (
                    "downtime_ms",
                    Json::from(report.downtime.as_millis_f64()),
                ),
            ]))
        }
        "services" => ok(Json::Arr(
            hv.service_names().into_iter().map(Json::from).collect(),
        )),
        "invoke_service" => {
            let user = parse_user(p)?;
            let service = p.str_field("service")?;
            let mults = p.u64_field("mults")?;
            let core = if service.contains("32") {
                "matmul32"
            } else {
                "matmul16"
            };
            let cfg = stream_config_for(core, mults)?;
            let svc = crate::service::BaaasService::with_scheduler(
                Arc::clone(&inner.sched),
            );
            let out = svc
                .invoke(user, service, &cfg)
                .map_err(|e| e.to_string())?;
            ok(outcome_json(&out))
        }
        "monitor" => {
            // One monitoring sweep over every device + report.
            let mut mon = crate::hypervisor::Monitor::new();
            mon.sample_all(hv);
            let report = mon.to_json();
            ok(Json::obj(vec![
                ("devices", report),
                (
                    "cloud_utilization",
                    Json::from(mon.cloud_utilization()),
                ),
            ]))
        }
        "workload" => {
            // Run a synthetic session workload (operator tooling /
            // capacity planning). Params: sessions, rate, hold_s.
            let w = crate::hypervisor::CloudWorkload {
                arrival_rate: p.get("rate").as_f64().unwrap_or(0.05),
                mean_hold_s: p.get("hold_s").as_f64().unwrap_or(120.0),
                sessions: p.get("sessions").as_u64().unwrap_or(40) as usize,
                seed: p.get("seed").as_u64().unwrap_or(0x10AD),
            };
            let report = crate::hypervisor::workload::run(hv, &w)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("served", Json::from(report.served)),
                ("rejected", Json::from(report.rejected)),
                (
                    "admission_rate",
                    Json::from(report.admission_rate()),
                ),
                (
                    "mean_setup_ms",
                    Json::from(report.mean_setup_ms),
                ),
                (
                    "mean_utilization",
                    Json::from(report.mean_utilization),
                ),
                (
                    "makespan_s",
                    Json::from(report.makespan.as_secs_f64()),
                ),
                ("energy_j", Json::from(report.energy_j)),
            ]))
        }
        "sched_status" => ok(inner.sched.status_json()),
        "quota_set" => {
            // Absent fields keep their current values; `max_vfpgas: 0`
            // restores an unlimited cap and a negative `budget_s`
            // clears the budget (the JSON layer cannot distinguish
            // null from absent). The merge runs atomically under the
            // scheduler's lock so concurrent partial updates cannot
            // lose each other's fields.
            let user = parse_user(p)?;
            let quota = inner.sched.update_quota(user, |q| {
                match p.get("max_vfpgas").as_u64() {
                    Some(0) => q.max_concurrent = u64::MAX,
                    Some(n) => q.max_concurrent = n,
                    None => {}
                }
                match p.get("budget_s").as_f64() {
                    Some(b) if b < 0.0 => q.device_seconds_budget = None,
                    Some(b) => q.device_seconds_budget = Some(b),
                    None => {}
                }
                if let Some(w) = p.get("weight").as_u64() {
                    q.weight = w.max(1);
                }
            });
            ok(quota_json(user, &quota, inner.sched.in_use(user)))
        }
        "quota_get" => {
            let user = parse_user(p)?;
            let quota = inner.sched.quota(user);
            ok(quota_json(user, &quota, inner.sched.in_use(user)))
        }
        "usage_report" => ok(Json::obj(vec![
            ("tenants", inner.sched.usage_json()),
            (
                "table",
                Json::from(inner.sched.usage_report()),
            ),
        ])),
        "reserve" => {
            let user = parse_user(p)?;
            let regions = p.u64_field("regions")?;
            let start_s = p.get("start_s").as_f64().unwrap_or_else(|| {
                hv.clock.now().as_secs_f64()
            });
            let duration_s =
                p.get("duration_s").as_f64().unwrap_or(3600.0);
            let id = inner.sched.reserve(
                user,
                regions,
                VirtualTime::from_secs_f64(start_s),
                VirtualTime::from_secs_f64(duration_s),
            );
            ok(Json::obj(vec![(
                "reservation",
                Json::from(id.to_string()),
            )]))
        }
        "cancel_reservation" => {
            let id = ReservationId::parse(p.str_field("reservation")?)
                .ok_or("bad reservation id")?;
            inner
                .sched
                .cancel_reservation(id)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![("cancelled", Json::from(true))]))
        }
        "energy" => ok(Json::obj(vec![
            ("joules", Json::from(hv.total_energy_joules())),
            ("power_w", Json::from(hv.total_power_w())),
        ])),
        "db_dump" => ok(hv.db.lock().unwrap().to_json()),
        "cores" => ok(Json::Arr(
            inner.cores.keys().cloned().map(Json::from).collect(),
        )),
        m => Err(format!("unknown method '{m}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn setup() -> (ManagementServer, Client, Arc<Hypervisor>) {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
        let client = Client::connect(server.addr()).unwrap();
        (server, client, hv)
    }

    #[test]
    fn hello_and_cores() {
        let (_s, mut c, _hv) = setup();
        let body = c.call("hello", Json::obj(vec![])).unwrap();
        assert_eq!(body.get("version").as_str(), Some(crate::VERSION));
        let cores = c.call("cores", Json::obj(vec![])).unwrap();
        assert!(cores
            .as_arr()
            .unwrap()
            .iter()
            .any(|c| c.as_str() == Some("matmul16")));
    }

    #[test]
    fn status_over_rc3e_costs_80ms() {
        let (_s, mut c, hv) = setup();
        let t0 = hv.clock.now();
        let body = c
            .call(
                "status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!(
            (ms - crate::paper::STATUS_RC3E_MS).abs() < 0.5,
            "status over RC3E took {ms} ms"
        );
        assert_eq!(body.get("regions_total").as_u64(), Some(4));
    }

    #[test]
    fn status_routes_through_registered_agent() {
        let (s, mut c, hv) = setup();
        let agent = super::super::agent::NodeAgent::spawn(
            Arc::clone(&hv),
            NodeId(0),
            None,
        )
        .unwrap();
        s.register_agent(NodeId(0), agent.addr());
        let t0 = hv.clock.now();
        let body = c
            .call(
                "status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        assert_eq!(body.get("board").as_str(), Some("vc707"));
        // Same virtual cost as the unrouted path (Table I: local vs
        // remote node over RC3E are both 80 ms).
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!((ms - 80.0).abs() < 0.5, "{ms}");
    }

    #[test]
    fn full_lease_cycle_over_rpc() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("cli"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let alloc = lease.get("alloc").as_str().unwrap().to_string();
        let prog = c
            .call(
                "program_core",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                ]),
            )
            .unwrap();
        // PR over RC3E ≈ 732 + 111 (orchestration); the RPC hop is
        // charged before dispatch.
        let pr_ms = prog.get("pr_ms").as_f64().unwrap();
        assert!((pr_ms - 843.0).abs() < 1.0, "{pr_ms}");
        c.call(
            "release",
            Json::obj(vec![("alloc", Json::from(alloc.as_str()))]),
        )
        .unwrap();
    }

    #[test]
    fn stream_over_rpc_returns_outcome() {
        if !crate::testing::artifacts_available(
            "middleware::stream_over_rpc_returns_outcome",
        ) {
            return;
        }
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("u"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let alloc = lease.get("alloc").as_str().unwrap().to_string();
        c.call(
            "program_core",
            Json::obj(vec![
                ("user", Json::from(user.as_str())),
                ("alloc", Json::from(alloc.as_str())),
                ("core", Json::from("matmul16")),
            ]),
        )
        .unwrap();
        let out = c
            .call(
                "stream",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                    ("mults", Json::from(512u64)),
                ]),
            )
            .unwrap();
        assert_eq!(out.get("validation_failures").as_u64(), Some(0));
        assert!(out.get("virtual_mbps").as_f64().unwrap() > 400.0);
    }

    #[test]
    fn errors_are_application_level() {
        let (_s, mut c, _hv) = setup();
        // Unknown method.
        assert!(c.call("reboot_world", Json::obj(vec![])).is_err());
        // Bad params.
        assert!(c
            .call("status", Json::obj(vec![("fpga", Json::from("x"))]))
            .is_err());
        // Connection survives both errors.
        assert!(c.call("hello", Json::obj(vec![])).is_ok());
    }

    #[test]
    fn db_dump_is_valid_json_db() {
        let (_s, mut c, _hv) = setup();
        let dump = c.call("db_dump", Json::obj(vec![])).unwrap();
        let db = crate::hypervisor::DeviceDb::from_json(&dump).unwrap();
        assert_eq!(db.devices.len(), 4);
    }

    #[test]
    fn quota_rpcs_roundtrip_and_enforce() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("q"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let set = c
            .call(
                "quota_set",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("max_vfpgas", Json::from(1u64)),
                    ("weight", Json::from(3u64)),
                ]),
            )
            .unwrap();
        assert_eq!(set.get("max_vfpgas").as_u64(), Some(1));
        let got = c
            .call(
                "quota_get",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        assert_eq!(got.get("weight").as_u64(), Some(3));
        // First lease fits the quota; the second is denied.
        c.call(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from(user.as_str()))]),
        )
        .unwrap();
        let err = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap_err();
        assert!(err.contains("quota"), "{err}");
    }

    #[test]
    fn sched_status_and_usage_rpcs() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("u"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let status =
            c.call("sched_status", Json::obj(vec![])).unwrap();
        assert_eq!(status.get("active_grants").as_u64(), Some(1));
        assert_eq!(status.get("queue_depth").as_u64(), Some(0));
        c.call(
            "release",
            Json::obj(vec![(
                "alloc",
                Json::from(lease.get("alloc").as_str().unwrap()),
            )]),
        )
        .unwrap();
        let usage = c.call("usage_report", Json::obj(vec![])).unwrap();
        let tenants = usage.get("tenants").as_arr().unwrap();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].get("released").as_u64(), Some(1));
        assert!(usage
            .get("table")
            .as_str()
            .unwrap()
            .contains("tenant"));
    }

    #[test]
    fn reservation_rpcs_withhold_capacity() {
        let (_s, mut c, _hv) = setup();
        let mk_user = |c: &mut Client, name: &str| {
            c.call(
                "add_user",
                Json::obj(vec![("name", Json::from(name))]),
            )
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string()
        };
        let holder = mk_user(&mut c, "holder");
        let other = mk_user(&mut c, "other");
        // Reserve the whole 16-region testbed for the holder.
        let r = c
            .call(
                "reserve",
                Json::obj(vec![
                    ("user", Json::from(holder.as_str())),
                    ("regions", Json::from(16u64)),
                    ("duration_s", Json::from(10_000.0)),
                ]),
            )
            .unwrap();
        let err = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(other.as_str()))]),
            )
            .unwrap_err();
        assert!(err.contains("no capacity"), "{err}");
        c.call(
            "cancel_reservation",
            Json::obj(vec![(
                "reservation",
                Json::from(r.get("reservation").as_str().unwrap()),
            )]),
        )
        .unwrap();
        assert!(c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(other.as_str()))]),
            )
            .is_ok());
    }
}
