//! The management-node server: the middleware entry point users talk
//! to (the CLI connects here).
//!
//! Every incoming request charges the cluster's RPC overhead to the
//! virtual clock (Table I: the RC3E hop turns an 11 ms local status
//! call into 80 ms) and then dispatches into the hypervisor. Device
//! status is routed through the owning node's [`super::NodeAgent`]
//! when one is registered — the management→node Ethernet hop.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::client::Client;
use super::proto::{read_frame, write_frame, Request, Response};
use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hls::synth::{CoreKind, CoreSpec, Synthesizer};
use crate::hypervisor::Hypervisor;
use crate::rc2f::stream::StreamConfig;
use crate::util::clock::VirtualTime;
use crate::util::ids::{AllocationId, FpgaId, NodeId, UserId};
use crate::util::json::Json;

/// The management server (owns its accept thread).
pub struct ManagementServer {
    inner: Arc<ServerInner>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

struct ServerInner {
    hv: Arc<Hypervisor>,
    rpc_overhead_ms: f64,
    /// Prebuilt relocatable user-core bitfiles ("the user uploads a
    /// bitfile" — kept server-side so the CLI can reference cores by
    /// name).
    cores: BTreeMap<String, Bitstream>,
    /// node → agent address for routed device ops.
    agents: Mutex<BTreeMap<NodeId, SocketAddr>>,
}

impl ManagementServer {
    /// Spawn on an ephemeral loopback port.
    pub fn spawn(
        hv: Arc<Hypervisor>,
        rpc_overhead_ms: f64,
    ) -> std::io::Result<ManagementServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let inner = Arc::new(ServerInner {
            hv,
            rpc_overhead_ms,
            cores: build_core_library(),
            agents: Mutex::new(BTreeMap::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let inner2 = Arc::clone(&inner);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let inner = Arc::clone(&inner2);
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, inner);
                });
            }
        });
        Ok(ManagementServer {
            inner,
            addr,
            stop,
            handle: Some(handle),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Register a node agent for routed status calls.
    pub fn register_agent(&self, node: NodeId, addr: SocketAddr) {
        self.inner.agents.lock().unwrap().insert(node, addr);
    }

    /// Names of the prebuilt user cores the server can program.
    pub fn core_names(&self) -> Vec<String> {
        self.inner.cores.keys().cloned().collect()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ManagementServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Build the server's core library: one relocatable bitfile per known
/// core (synth report resources, slot-0 frames — retargeted at
/// program time).
fn build_core_library() -> BTreeMap<String, Bitstream> {
    let synth = Synthesizer::new();
    let mut lib = BTreeMap::new();
    let entries: Vec<(&str, CoreKind, usize)> = vec![
        ("matmul16", CoreKind::MatMul { n: 16 }, 256),
        ("matmul16_small", CoreKind::MatMul { n: 16 }, 64),
        ("matmul32", CoreKind::MatMul { n: 32 }, 64),
        ("loopback", CoreKind::Loopback, 256),
        ("saxpy", CoreKind::Saxpy, 256),
        ("checksum", CoreKind::Checksum, 256),
    ];
    for (name, kind, batch) in entries {
        let spec = CoreSpec::named(kind, "xc7vx485t");
        let report = synth.synthesize(&spec);
        let total = report.total_for(1);
        let mut b = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            &kind.name(),
        )
        .resources(total)
        .frames(crate::hls::flow::region_window(0, 1));
        if let Some(a) = spec.artifact(batch) {
            b = b.artifact(&a);
        }
        lib.insert(name.to_string(), b.build());
    }
    lib
}

fn serve_conn(
    mut stream: TcpStream,
    inner: Arc<ServerInner>,
) -> std::io::Result<()> {
    while let Some(frame) = read_frame(&mut stream)? {
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::error(&e),
            Ok(req) => {
                // The RC3E middleware hop (Table I's +69 ms).
                inner.hv.clock.advance(VirtualTime::from_millis_f64(
                    inner.rpc_overhead_ms,
                ));
                dispatch(&inner, &req)
                    .unwrap_or_else(|e| Response::error(&e))
            }
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

fn parse_user(params: &Json) -> Result<UserId, String> {
    UserId::parse(params.str_field("user")?)
        .ok_or_else(|| "bad user id".to_string())
}

fn parse_alloc(params: &Json) -> Result<AllocationId, String> {
    AllocationId::parse(params.str_field("alloc")?)
        .ok_or_else(|| "bad alloc id".to_string())
}

fn stream_config_for(
    core: &str,
    mults: u64,
) -> Result<StreamConfig, String> {
    match core {
        "matmul16" => Ok(StreamConfig::matmul16(mults)),
        "matmul32" => Ok(StreamConfig::matmul32(mults)),
        c => Err(format!("no stream profile for core '{c}'")),
    }
}

fn outcome_json(out: &crate::rc2f::stream::StreamOutcome) -> Json {
    Json::obj(vec![
        ("artifact", Json::from(out.artifact.as_str())),
        ("mults", Json::from(out.mults)),
        ("input_bytes", Json::from(out.input_bytes)),
        ("output_bytes", Json::from(out.output_bytes)),
        (
            "virtual_stream_s",
            Json::from(out.virtual_stream.as_secs_f64()),
        ),
        (
            "virtual_total_s",
            Json::from(out.virtual_total.as_secs_f64()),
        ),
        ("virtual_mbps", Json::from(out.virtual_mbps())),
        ("wall_s", Json::from(out.wall_secs)),
        ("wall_mbps", Json::from(out.wall_mbps())),
        ("checksum", Json::from(out.checksum)),
        (
            "validation_failures",
            Json::from(out.validation_failures),
        ),
    ])
}

fn dispatch(inner: &ServerInner, req: &Request) -> Result<Response, String> {
    let hv = &inner.hv;
    let p = &req.params;
    let ok = |j: Json| Ok(Response::success(j));
    match req.method.as_str() {
        "hello" => ok(Json::obj(vec![
            ("version", Json::from(crate::VERSION)),
            ("service", Json::from("rc3e-management")),
        ])),
        "add_user" => {
            let name = p.str_field("name")?;
            let id = hv.add_user(name);
            ok(Json::obj(vec![("user", Json::from(id.to_string()))]))
        }
        "status" => {
            let fpga = FpgaId::parse(p.str_field("fpga")?)
                .ok_or("bad fpga id")?;
            // Route via the owning node's agent when registered.
            let node = hv
                .device(fpga)
                .map_err(|e| e.to_string())?
                .node;
            let agent_addr =
                inner.agents.lock().unwrap().get(&node).copied();
            if let Some(addr) = agent_addr {
                let mut agent = Client::connect(addr)?;
                let body = agent.call(
                    "agent.status",
                    Json::obj(vec![(
                        "fpga",
                        Json::from(fpga.to_string()),
                    )]),
                )?;
                return Ok(Response::success(body));
            }
            let st = hv.status_local(fpga).map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("fpga", Json::from(st.fpga.to_string())),
                ("board", Json::from(st.board)),
                ("regions_total", Json::from(st.regions_total)),
                (
                    "regions_configured",
                    Json::from(st.regions_configured),
                ),
                ("regions_clocked", Json::from(st.regions_clocked)),
                ("power_w", Json::from(st.power_w)),
            ]))
        }
        "alloc_vfpga" => {
            let user = parse_user(p)?;
            let model = p
                .get("model")
                .as_str()
                .and_then(ServiceModel::parse)
                .unwrap_or(ServiceModel::RAaaS);
            let (alloc, vfpga, fpga, node) = hv
                .alloc_vfpga(user, model)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("alloc", Json::from(alloc.to_string())),
                ("vfpga", Json::from(vfpga.to_string())),
                ("fpga", Json::from(fpga.to_string())),
                ("node", Json::from(node.to_string())),
            ]))
        }
        "alloc_physical" => {
            let user = parse_user(p)?;
            let (alloc, fpga, node) = hv
                .alloc_physical(user, None)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("alloc", Json::from(alloc.to_string())),
                ("fpga", Json::from(fpga.to_string())),
                ("node", Json::from(node.to_string())),
            ]))
        }
        "release" => {
            let alloc = parse_alloc(p)?;
            hv.release(alloc).map_err(|e| e.to_string())?;
            ok(Json::obj(vec![("released", Json::from(true))]))
        }
        "program_core" => {
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            let core = p.str_field("core")?;
            let bitfile = inner
                .cores
                .get(core)
                .ok_or_else(|| format!("unknown core '{core}'"))?;
            let vfpga = hv
                .check_vfpga_lease(alloc, user)
                .map_err(|e| e.to_string())?;
            let (slot, quarters) = {
                let db = hv.db.lock().unwrap();
                let fpga = db
                    .device_of_vfpga(vfpga)
                    .ok_or("vfpga has no device")?
                    .id;
                drop(db);
                let dev = hv.device(fpga).map_err(|e| e.to_string())?;
                let slot = dev.slot_of[&vfpga];
                let q = dev
                    .fpga
                    .lock()
                    .unwrap()
                    .region(vfpga)
                    .map_err(|e| e.to_string())?
                    .shape
                    .quarters();
                (slot, q)
            };
            let placed = crate::hls::flow::DesignFlow::retarget(
                bitfile, slot, quarters,
            );
            let d = hv
                .program_vfpga(alloc, user, &placed)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("programmed", Json::from(core)),
                ("pr_ms", Json::from(d.as_millis_f64())),
            ]))
        }
        "stream" => {
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            let core = p.str_field("core")?;
            let mults = p.u64_field("mults")?;
            let cfg = stream_config_for(core, mults)?;
            let svc = crate::service::RaaasService::new(Arc::clone(hv));
            let out = svc
                .stream(alloc, user, &cfg)
                .map_err(|e| e.to_string())?;
            ok(outcome_json(&out))
        }
        "program_full" => {
            // RSaaS: write a full user bitstream to an exclusively
            // held device (server builds the synthetic image; a real
            // deployment would receive an upload).
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            let name = p.get("name").as_str().unwrap_or("user_design");
            let part = {
                let db = hv.db.lock().unwrap();
                let fpga = db
                    .allocations
                    .get(&alloc)
                    .and_then(|a| match a.kind {
                        crate::hypervisor::AllocKind::Physical(f)
                        | crate::hypervisor::AllocKind::Vm(_, f) => Some(f),
                        _ => None,
                    })
                    .ok_or("allocation is not physical")?;
                drop(db);
                hv.device(fpga).map_err(|e| e.to_string())?.fpga
                    .lock()
                    .unwrap()
                    .board
                    .part
            };
            let bs = crate::bitstream::BitstreamBuilder::full(part, name)
                .build();
            let d = hv
                .program_full(alloc, user, &bs)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("programmed", Json::from(name)),
                ("config_s", Json::from(d.as_secs_f64())),
            ]))
        }
        "migrate" => {
            let user = parse_user(p)?;
            let alloc = parse_alloc(p)?;
            let report = hv
                .migrate_vfpga(alloc, user, None)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("from", Json::from(report.from.to_string())),
                ("to", Json::from(report.to.to_string())),
                (
                    "cross_device",
                    Json::from(report.moved_across_devices),
                ),
                (
                    "downtime_ms",
                    Json::from(report.downtime.as_millis_f64()),
                ),
            ]))
        }
        "services" => ok(Json::Arr(
            hv.service_names().into_iter().map(Json::from).collect(),
        )),
        "invoke_service" => {
            let user = parse_user(p)?;
            let service = p.str_field("service")?;
            let mults = p.u64_field("mults")?;
            let core = if service.contains("32") {
                "matmul32"
            } else {
                "matmul16"
            };
            let cfg = stream_config_for(core, mults)?;
            let svc = crate::service::BaaasService::new(Arc::clone(hv));
            let out = svc
                .invoke(user, service, &cfg)
                .map_err(|e| e.to_string())?;
            ok(outcome_json(&out))
        }
        "monitor" => {
            // One monitoring sweep over every device + report.
            let mut mon = crate::hypervisor::Monitor::new();
            mon.sample_all(hv);
            let report = mon.to_json();
            ok(Json::obj(vec![
                ("devices", report),
                (
                    "cloud_utilization",
                    Json::from(mon.cloud_utilization()),
                ),
            ]))
        }
        "workload" => {
            // Run a synthetic session workload (operator tooling /
            // capacity planning). Params: sessions, rate, hold_s.
            let w = crate::hypervisor::CloudWorkload {
                arrival_rate: p.get("rate").as_f64().unwrap_or(0.05),
                mean_hold_s: p.get("hold_s").as_f64().unwrap_or(120.0),
                sessions: p.get("sessions").as_u64().unwrap_or(40) as usize,
                seed: p.get("seed").as_u64().unwrap_or(0x10AD),
            };
            let report = crate::hypervisor::workload::run(hv, &w)
                .map_err(|e| e.to_string())?;
            ok(Json::obj(vec![
                ("served", Json::from(report.served)),
                ("rejected", Json::from(report.rejected)),
                (
                    "admission_rate",
                    Json::from(report.admission_rate()),
                ),
                (
                    "mean_setup_ms",
                    Json::from(report.mean_setup_ms),
                ),
                (
                    "mean_utilization",
                    Json::from(report.mean_utilization),
                ),
                (
                    "makespan_s",
                    Json::from(report.makespan.as_secs_f64()),
                ),
                ("energy_j", Json::from(report.energy_j)),
            ]))
        }
        "energy" => ok(Json::obj(vec![
            ("joules", Json::from(hv.total_energy_joules())),
            ("power_w", Json::from(hv.total_power_w())),
        ])),
        "db_dump" => ok(hv.db.lock().unwrap().to_json()),
        "cores" => ok(Json::Arr(
            inner.cores.keys().cloned().map(Json::from).collect(),
        )),
        m => Err(format!("unknown method '{m}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn setup() -> (ManagementServer, Client, Arc<Hypervisor>) {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
        let client = Client::connect(server.addr()).unwrap();
        (server, client, hv)
    }

    #[test]
    fn hello_and_cores() {
        let (_s, mut c, _hv) = setup();
        let body = c.call("hello", Json::obj(vec![])).unwrap();
        assert_eq!(body.get("version").as_str(), Some(crate::VERSION));
        let cores = c.call("cores", Json::obj(vec![])).unwrap();
        assert!(cores
            .as_arr()
            .unwrap()
            .iter()
            .any(|c| c.as_str() == Some("matmul16")));
    }

    #[test]
    fn status_over_rc3e_costs_80ms() {
        let (_s, mut c, hv) = setup();
        let t0 = hv.clock.now();
        let body = c
            .call(
                "status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!(
            (ms - crate::paper::STATUS_RC3E_MS).abs() < 0.5,
            "status over RC3E took {ms} ms"
        );
        assert_eq!(body.get("regions_total").as_u64(), Some(4));
    }

    #[test]
    fn status_routes_through_registered_agent() {
        let (s, mut c, hv) = setup();
        let agent = super::super::agent::NodeAgent::spawn(
            Arc::clone(&hv),
            NodeId(0),
            None,
        )
        .unwrap();
        s.register_agent(NodeId(0), agent.addr());
        let t0 = hv.clock.now();
        let body = c
            .call(
                "status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        assert_eq!(body.get("board").as_str(), Some("vc707"));
        // Same virtual cost as the unrouted path (Table I: local vs
        // remote node over RC3E are both 80 ms).
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!((ms - 80.0).abs() < 0.5, "{ms}");
    }

    #[test]
    fn full_lease_cycle_over_rpc() {
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("cli"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let alloc = lease.get("alloc").as_str().unwrap().to_string();
        let prog = c
            .call(
                "program_core",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                ]),
            )
            .unwrap();
        // PR over RC3E ≈ 732 + 111 (orchestration); the RPC hop is
        // charged before dispatch.
        let pr_ms = prog.get("pr_ms").as_f64().unwrap();
        assert!((pr_ms - 843.0).abs() < 1.0, "{pr_ms}");
        c.call(
            "release",
            Json::obj(vec![("alloc", Json::from(alloc.as_str()))]),
        )
        .unwrap();
    }

    #[test]
    fn stream_over_rpc_returns_outcome() {
        if !crate::runtime::artifact_dir().join("manifest.json").exists() {
            return;
        }
        let (_s, mut c, _hv) = setup();
        let user = c
            .call("add_user", Json::obj(vec![("name", Json::from("u"))]))
            .unwrap()
            .get("user")
            .as_str()
            .unwrap()
            .to_string();
        let lease = c
            .call(
                "alloc_vfpga",
                Json::obj(vec![("user", Json::from(user.as_str()))]),
            )
            .unwrap();
        let alloc = lease.get("alloc").as_str().unwrap().to_string();
        c.call(
            "program_core",
            Json::obj(vec![
                ("user", Json::from(user.as_str())),
                ("alloc", Json::from(alloc.as_str())),
                ("core", Json::from("matmul16")),
            ]),
        )
        .unwrap();
        let out = c
            .call(
                "stream",
                Json::obj(vec![
                    ("user", Json::from(user.as_str())),
                    ("alloc", Json::from(alloc.as_str())),
                    ("core", Json::from("matmul16")),
                    ("mults", Json::from(512u64)),
                ]),
            )
            .unwrap();
        assert_eq!(out.get("validation_failures").as_u64(), Some(0));
        assert!(out.get("virtual_mbps").as_f64().unwrap() > 400.0);
    }

    #[test]
    fn errors_are_application_level() {
        let (_s, mut c, _hv) = setup();
        // Unknown method.
        assert!(c.call("reboot_world", Json::obj(vec![])).is_err());
        // Bad params.
        assert!(c
            .call("status", Json::obj(vec![("fpga", Json::from("x"))]))
            .is_err());
        // Connection survives both errors.
        assert!(c.call("hello", Json::obj(vec![])).is_ok());
    }

    #[test]
    fn db_dump_is_valid_json_db() {
        let (_s, mut c, _hv) = setup();
        let dump = c.call("db_dump", Json::obj(vec![])).unwrap();
        let db = crate::hypervisor::DeviceDb::from_json(&dump).unwrap();
        assert_eq!(db.devices.len(), 4);
    }
}
