//! The management-node server: the middleware entry point users talk
//! to (the CLI connects here).
//!
//! Every incoming request charges the cluster's RPC overhead to the
//! virtual clock (Table I: the RC3E hop turns an 11 ms local status
//! call into 80 ms) and then dispatches through a table of *typed*
//! handlers — one [`Method`] → handler entry per RPC, each parsing a
//! typed request struct from [`super::api`] and serializing a typed
//! response. No handler reads raw params inline, and every failure
//! leaves the server as a structured [`ApiError`].
//!
//! Long-running operations (`program_full`, `stream`,
//! `invoke_service`) run as registry jobs ([`super::jobs`]): the
//! caller gets a `job_id` back immediately and drives `job_status` /
//! `job_wait` / `job_cancel`. Workers emit [`Event::JobProgress`]
//! frames at phase boundaries and stream checkpoints; `job_wait`
//! callers coalesce on a shared per-job wakeup slot.
//!
//! Protocol 3 adds the server-push surface: `subscribe` turns the
//! connection into a multi-frame event stream fed by the process-wide
//! [`EventBus`] — the job registry, the scheduler sink and the
//! per-device transition sink all publish into it. Protocol 1 (the
//! untyped surface) is retired: proto-less requests are rejected with
//! `protocol_mismatch` before dispatch.
//!
//! Device status is routed through the owning node's
//! [`super::NodeAgent`] when one is registered — the management→node
//! Ethernet hop.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::api::*;
use super::client::Client;
use super::events::{EventBus, Scope};
use super::jobs::{
    JobRegistry, ProgressReporter, DEFAULT_WAIT_S, MAX_WAIT_S,
};
use super::proto::{
    read_frame, read_wire_frame, respond, write_bin_frame,
    write_data_frame, write_frame, BinFrame, Request, Response,
    StreamFrame, WireFrame,
};
use crate::bitcache::{BitstreamCache, CompileService, Prefetcher};
use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::fpga::board::{BoardKind, BoardSpec};
use crate::hls::synth::{CoreKind, CoreSpec, Synthesizer};
use crate::hypervisor::{AllocKind, Hypervisor, HypervisorError};
use crate::rc2f::stream::StreamConfig;
use crate::sched::{
    AdmissionRequest, Lease, PreemptPolicy, RequestClass, SchedEvent,
    Scheduler,
};
use crate::util::clock::VirtualTime;
use crate::util::ids::{AllocationId, LeaseToken, NodeId};
use crate::util::json::Json;
use crate::util::trace::Tracer;

/// The management server (owns its accept thread, and in federated
/// mode the heartbeat monitor too).
pub struct ManagementServer {
    inner: Arc<ServerInner>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    health: Option<crate::cluster::HealthMonitor>,
}

struct ServerInner {
    hv: Arc<Hypervisor>,
    /// The cluster scheduler — every allocation RPC admits through it.
    sched: Arc<Scheduler>,
    /// Async jobs for the long-running RPCs.
    jobs: Arc<JobRegistry>,
    /// The protocol-3 server-push event bus.
    bus: Arc<EventBus>,
    /// The flight recorder: every RPC opens a root span here.
    tracer: Arc<Tracer>,
    rpc_overhead_ms: f64,
    /// Prebuilt relocatable user-core bitfiles ("the user uploads a
    /// bitfile" — kept server-side so the CLI can reference cores by
    /// name).
    cores: BTreeMap<String, Bitstream>,
    /// node → agent address for routed device ops.
    agents: Mutex<BTreeMap<NodeId, SocketAddr>>,
    /// Federation coordinator (`Some` on `spawn_federated` servers):
    /// admissions route across registered node daemons instead of
    /// the local hypervisor.
    cluster: Option<Arc<crate::cluster::Coordinator>>,
    /// Cluster-wide content-addressed bitstream cache (the warm
    /// program tier; persists under `--state DIR/bitcache`).
    cache: Arc<BitstreamCache>,
    /// AOT compile service fronting the HLS flow (`compile_submit`).
    compiler: Arc<CompileService>,
    /// Admission-driven prefetcher fed by the scheduler's queue sink.
    prefetch: Arc<Prefetcher>,
}

/// Artifacts the management cache keeps resident before LRU eviction.
const BITCACHE_CAPACITY: usize = 32;

/// Payload chunk size for `agent.fetch_bitstream` data frames.
const FETCH_CHUNK: usize = 4096;

impl ManagementServer {
    /// Spawn on an ephemeral loopback port (no durable state).
    pub fn spawn(
        hv: Arc<Hypervisor>,
        rpc_overhead_ms: f64,
    ) -> std::io::Result<ManagementServer> {
        ManagementServer::spawn_with_state(hv, rpc_overhead_ms, None)
    }

    /// Spawn with an optional durable state directory. When set, the
    /// event bus journals every published event under
    /// `state_dir/events/` (opened *before* any traffic, so every
    /// cursor a client ever sees is on disk) and `subscribe` resume
    /// via `from_cursor` replays across restarts. Scheduler WAL state
    /// lives next to the snapshot and is wired separately via
    /// [`crate::sched::Scheduler::attach_persistence`].
    pub fn spawn_with_state(
        hv: Arc<Hypervisor>,
        rpc_overhead_ms: f64,
        state_dir: Option<&std::path::Path>,
    ) -> std::io::Result<ManagementServer> {
        ManagementServer::spawn_inner(hv, rpc_overhead_ms, state_dir, false)
    }

    /// Spawn a *federated* management server: the hypervisor here is
    /// deviceless (capacity lives on node daemons that register via
    /// `cluster.register`), admissions route across the cluster
    /// through the [`crate::cluster::Coordinator`], and a heartbeat
    /// monitor drives failure detection + lease re-admission.
    pub fn spawn_federated(
        hv: Arc<Hypervisor>,
        rpc_overhead_ms: f64,
        state_dir: Option<&std::path::Path>,
    ) -> std::io::Result<ManagementServer> {
        ManagementServer::spawn_inner(hv, rpc_overhead_ms, state_dir, true)
    }

    fn spawn_inner(
        hv: Arc<Hypervisor>,
        rpc_overhead_ms: f64,
        state_dir: Option<&std::path::Path>,
        federated: bool,
    ) -> std::io::Result<ManagementServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let sched = Scheduler::new(Arc::clone(&hv));
        let bus = EventBus::new();
        bus.set_metrics(Arc::clone(&hv.metrics));
        if let Some(dir) = state_dir {
            let journal = crate::journal::EventJournal::open(
                &dir.join("events"),
            )?;
            journal.set_metrics(Arc::clone(&hv.metrics));
            bus.attach_journal(Arc::new(journal));
        }
        let jobs = JobRegistry::new();
        jobs.set_metrics(Arc::clone(&hv.metrics));
        jobs.set_bus(Arc::clone(&bus));
        wire_event_sources(&hv, &sched, &bus);
        let cache = Arc::new(BitstreamCache::open(
            BITCACHE_CAPACITY,
            state_dir,
            Arc::clone(&hv.metrics),
        ));
        let compiler = Arc::new(CompileService::new(
            Arc::clone(&jobs),
            Arc::clone(&cache),
            Arc::clone(&hv.metrics),
        ));
        let prefetch = Arc::new(Prefetcher::new(
            Arc::clone(&compiler),
            Arc::clone(&hv.metrics),
        ));
        // Queued admissions warm the cache: the sink stays cheap (map
        // lookup + async job submit) per the scheduler's contract.
        let sink_prefetch = Arc::clone(&prefetch);
        sched.set_prefetch_sink(Arc::new(move |hint| {
            let _ = sink_prefetch.hint(&hint);
        }));
        let tracer = Tracer::new(Arc::clone(&hv.clock));
        let cluster = if federated {
            Some(crate::cluster::Coordinator::new(
                Arc::clone(&hv),
                Arc::clone(&bus),
            ))
        } else {
            None
        };
        let inner = Arc::new(ServerInner {
            hv,
            sched,
            jobs,
            bus,
            tracer,
            rpc_overhead_ms,
            cores: build_core_library(),
            agents: Mutex::new(BTreeMap::new()),
            cluster,
            cache,
            compiler,
            prefetch,
        });
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let inner2 = Arc::clone(&inner);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let inner = Arc::clone(&inner2);
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, inner);
                });
            }
        });
        let health = inner.cluster.as_ref().map(|cl| {
            crate::cluster::HealthMonitor::spawn(Arc::clone(cl))
        });
        Ok(ManagementServer {
            inner,
            addr,
            stop,
            handle: Some(handle),
            health,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The federation coordinator (`None` on non-federated servers).
    pub fn cluster(&self) -> Option<&Arc<crate::cluster::Coordinator>> {
        self.inner.cluster.as_ref()
    }

    /// Register a node agent for routed status calls.
    pub fn register_agent(&self, node: NodeId, addr: SocketAddr) {
        self.inner.agents.lock().unwrap().insert(node, addr);
    }

    /// Names of the prebuilt user cores the server can program.
    pub fn core_names(&self) -> Vec<String> {
        self.inner.cores.keys().cloned().collect()
    }

    /// The cluster scheduler behind this server.
    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.inner.sched
    }

    /// The async-job registry behind this server.
    pub fn jobs(&self) -> &Arc<JobRegistry> {
        &self.inner.jobs
    }

    /// The protocol-3 event bus behind this server.
    pub fn bus(&self) -> &Arc<EventBus> {
        &self.inner.bus
    }

    /// The flight recorder behind this server (benches toggle it).
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.inner.tracer
    }

    /// The cluster bitstream cache behind this server.
    pub fn bitcache(&self) -> &Arc<BitstreamCache> {
        &self.inner.cache
    }

    /// The AOT compile service behind this server.
    pub fn compiler(&self) -> &Arc<CompileService> {
        &self.inner.compiler
    }

    pub fn shutdown(&mut self) {
        if let Some(h) = &mut self.health {
            h.shutdown();
        }
        if let Some(cl) = &self.inner.cluster {
            cl.shutdown();
        }
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ManagementServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Plumb the scheduler's telemetry sink and every device's
/// lifecycle-transition sink into the event bus. Scopes encode the
/// tenant-isolation policy: queue depth / grants / region transitions
/// are operator telemetry (public), placement changes are
/// tenant-scoped, job progress is token-scoped (published by the job
/// registry itself).
pub(crate) fn wire_event_sources(
    hv: &Arc<Hypervisor>,
    sched: &Arc<Scheduler>,
    bus: &Arc<EventBus>,
) {
    let sink_bus = Arc::clone(bus);
    sched.set_event_sink(Arc::new(move |ev| {
        let (event, scope) = match ev {
            SchedEvent::QueueDepth { depth } => {
                (Event::QueueDepth { depth }, Scope::Public)
            }
            SchedEvent::GrantIssued {
                alloc,
                tenant,
                model,
                class,
                wait,
            } => (
                Event::GrantIssued {
                    alloc,
                    tenant,
                    model,
                    class,
                    wait_ms: wait.as_millis_f64(),
                },
                Scope::Public,
            ),
            SchedEvent::PlacementChanged {
                alloc,
                tenant,
                vfpga,
                fpga,
                migrations,
            } => (
                Event::LeasePlacementChanged {
                    alloc,
                    vfpga,
                    fpga,
                    migrations,
                },
                Scope::Tenant(tenant),
            ),
        };
        sink_bus.publish(event, scope);
    }));
    let region_bus = Arc::clone(bus);
    hv.set_region_transition_sink(Arc::new(move |fpga, rec| {
        region_bus.publish(
            Event::RegionTransition {
                fpga,
                region: rec.region,
                from: rec.from.name().to_string(),
                to: rec.to.name().to_string(),
                at_s: rec.at.as_secs_f64(),
            },
            Scope::Public,
        );
    }));
}

/// Build the server's core library: one relocatable bitfile per known
/// core (synth report resources, slot-0 frames — retargeted at
/// program time).
pub(crate) fn build_core_library() -> BTreeMap<String, Bitstream> {
    let synth = Synthesizer::new();
    let mut lib = BTreeMap::new();
    let entries: Vec<(&str, CoreKind, usize)> = vec![
        ("matmul16", CoreKind::MatMul { n: 16 }, 256),
        ("matmul16_small", CoreKind::MatMul { n: 16 }, 64),
        ("matmul32", CoreKind::MatMul { n: 32 }, 64),
        ("loopback", CoreKind::Loopback, 256),
        ("saxpy", CoreKind::Saxpy, 256),
        ("checksum", CoreKind::Checksum, 256),
    ];
    for (name, kind, batch) in entries {
        let spec = CoreSpec::named(kind, "xc7vx485t");
        let report = synth.synthesize(&spec);
        let total = report.total_for(1);
        let mut b = crate::bitstream::BitstreamBuilder::partial(
            "xc7vx485t",
            &kind.name(),
        )
        .resources(total)
        .frames(crate::hls::flow::region_window(0, 1));
        if let Some(a) = spec.artifact(batch) {
            b = b.artifact(&a);
        }
        lib.insert(name.to_string(), b.build());
    }
    lib
}

fn serve_conn(
    mut stream: TcpStream,
    inner: Arc<ServerInner>,
) -> std::io::Result<()> {
    while let Some(frame) = read_frame(&mut stream)? {
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::failure(None, ApiError::bad_request(e)),
            Ok(req) => {
                // The RC3E middleware hop (Table I's +69 ms).
                inner.hv.clock.advance(VirtualTime::from_millis_f64(
                    inner.rpc_overhead_ms,
                ));
                match req.negotiate_proto() {
                    Err(e) => respond(req.id, Err(e)),
                    Ok(proto)
                        if req.method == Method::Subscribe.name() =>
                    {
                        // Multi-frame response: the handler writes the
                        // header + event frames + terminal frame
                        // itself, then the connection returns to
                        // request/response mode. The root span covers
                        // the whole subscription window.
                        let _root = inner
                            .tracer
                            .root("rpc.subscribe", req.trace);
                        serve_subscription(
                            &mut stream,
                            &inner,
                            proto,
                            req.id,
                            &req.params,
                        )?;
                        continue;
                    }
                    Ok(proto)
                        if req.method
                            == Method::AgentFetchBitstream.name() =>
                    {
                        // Artifact transfer: header + payload frames
                        // + terminal, served out-of-table like the
                        // data plane below.
                        let _root = inner
                            .tracer
                            .root("rpc.fetch_bitstream", req.trace);
                        serve_fetch_bitstream(
                            &mut stream,
                            &inner,
                            proto,
                            req.id,
                            &req.params,
                        )?;
                        continue;
                    }
                    Ok(proto) if wants_stream_data(&req) => {
                        // Data-plane reply: header + raw output
                        // frames + terminal, synchronous on the
                        // connection like `subscribe`.
                        let _root = inner
                            .tracer
                            .root("rpc.stream_data", req.trace);
                        serve_stream_data(
                            &mut stream,
                            &inner,
                            proto,
                            req.id,
                            &req.params,
                        )?;
                        continue;
                    }
                    Ok(_proto) => {
                        // Root span per RPC: the client's `trace`
                        // field (if any) stitches this request into an
                        // existing trace; otherwise a fresh trace
                        // starts here.
                        let root = inner.tracer.root(
                            &format!("rpc.{}", req.method),
                            req.trace,
                        );
                        let ctx = Ctx { inner: &inner };
                        let result =
                            dispatch(&ctx, &req.method, &req.params);
                        if let Err(e) = &result {
                            root.fail(&e.message);
                        }
                        drop(root);
                        respond(req.id, result)
                    }
                }
            }
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

// ================================================== subscriptions

/// Parse + authorize one `subscribe` request and register the
/// subscription on the bus. The tenant scope comes from the
/// presented capability, never from a client-chosen field: tokens
/// the scheduler does not know (job-scoped owner tokens, forged
/// tokens) resolve to no tenant — token-scoped events still match by
/// exact token, and a forged token simply matches nothing.
fn open_subscription(
    inner: &Arc<ServerInner>,
    proto: u32,
    params: &Json,
) -> Result<(Arc<super::events::Subscription>, SubscribeRequest), ApiError>
{
    if proto < 3 {
        return Err(ApiError::bad_request(
            "subscribe requires protocol 3",
        ));
    }
    let req = SubscribeRequest::from_json(params)?;
    let tenant = req
        .lease
        .and_then(|t| inner.sched.lease_handle(t))
        .map(|h| h.tenant());
    let sub = inner.bus.subscribe(req.filter.clone(), req.lease, tenant);
    Ok((sub, req))
}

/// Serve one `subscribe` request: header, ordered event frames,
/// terminal frame. Bounded by the (clamped) timeout and the optional
/// event budget, so a subscription can never outlive the client's
/// socket read timeout between frames.
fn serve_subscription(
    stream: &mut TcpStream,
    inner: &Arc<ServerInner>,
    proto: u32,
    id: Option<u64>,
    params: &Json,
) -> std::io::Result<()> {
    let (sub, req) = match open_subscription(inner, proto, params) {
        Err(e) => {
            return write_frame(
                stream,
                &Response::failure(id, e).to_json(),
            )
        }
        Ok(v) => v,
    };
    let timeout_s = req
        .timeout_s
        .unwrap_or(DEFAULT_WAIT_S)
        .clamp(0.01, MAX_WAIT_S);
    let header = Response::stream_header(
        id,
        SubscribeResponse {
            subscription: sub.id(),
            timeout_s,
        }
        .to_json(),
    );
    let deadline = Instant::now() + Duration::from_secs_f64(timeout_s);
    let max_events = req.max_events.unwrap_or(u64::MAX);
    let mut seq = 0u64;
    let result = (|| {
        write_frame(stream, &header.to_json())?;
        // Resume: replay the journaled gap first. The subscription is
        // already registered on the bus, so any event published after
        // the replay read lands in its live queue; events seen both
        // ways are deduplicated by cursor below. That overlap
        // discipline makes resume gapless and duplicate-free.
        let mut last_cursor = 0u64;
        if let Some(from) = req.from_cursor {
            for (cursor, ev) in inner.bus.replay_for(&sub, from) {
                if seq >= max_events {
                    break;
                }
                seq += 1;
                last_cursor = cursor;
                write_frame(
                    stream,
                    &StreamFrame::event(seq, ev.to_json())
                        .with_cursor(cursor)
                        .to_json(),
                )?;
            }
        }
        while seq < max_events {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match sub.next_with_cursor(deadline - now) {
                Some((cursor, ev)) => {
                    // Already delivered during replay.
                    if cursor <= last_cursor {
                        continue;
                    }
                    seq += 1;
                    write_frame(
                        stream,
                        &StreamFrame::event(seq, ev.to_json())
                            .with_cursor(cursor)
                            .to_json(),
                    )?;
                }
                None => break,
            }
        }
        // Terminal frame carries the subscription's backpressure
        // stats: what was delivered, what the bounded queue dropped,
        // and how deep it ever got.
        let stats = Json::obj(vec![
            ("delivered", Json::from(sub.delivered())),
            ("dropped", Json::from(sub.dropped())),
            ("queue_high_water", Json::from(sub.high_water())),
        ]);
        write_frame(
            stream,
            &StreamFrame::terminal_with_stats(seq + 1, None, stats)
                .to_json(),
        )
    })();
    inner.bus.unsubscribe(sub.id());
    result
}

// =================================================== data plane

/// Whether the request opts into the multi-frame data-plane reply
/// (`stream` with `emit_output: true`) — served out-of-table like
/// `subscribe`, since the response is header + data frames +
/// terminal rather than a single envelope.
fn wants_stream_data(req: &Request) -> bool {
    req.method == Method::Stream.name()
        && req.params.get("emit_output").as_bool().unwrap_or(false)
}

/// Serve one `stream` request with `emit_output`: a JSON header,
/// then the raw output bytes as data frames — out-of-band binary
/// frames for protocol-4 clients, base64 `stream_data` events for
/// protocol 3 — then a JSON terminal frame whose `stats` carry the
/// [`StreamOutcomeBody`]. The job registry is bypassed: the data
/// plane is synchronous on the connection. Federated deployments
/// relay the same frames from the owning node's daemon.
fn serve_stream_data(
    stream: &mut TcpStream,
    inner: &Arc<ServerInner>,
    proto: u32,
    id: Option<u64>,
    params: &Json,
) -> std::io::Result<()> {
    let binary = proto >= PROTO_DATA_FRAMES;
    let parsed = if proto < 3 {
        Err(ApiError::bad_request("emit_output requires protocol 3"))
    } else {
        StreamRequest::from_json(params)
    };
    let req = match parsed {
        Err(e) => {
            return write_frame(
                stream,
                &Response::failure(id, e).to_json(),
            )
        }
        Ok(r) => r,
    };
    if let Some(cl) = &inner.cluster {
        return relay_stream_data(stream, inner, cl, proto, id, &req);
    }
    // Resolve + authorize before the header: failures up to here are
    // plain single-frame error responses.
    let prep = (|| {
        let cfg = stream_config_for(&req.core, req.mults)?;
        let ctx = Ctx { inner };
        let handle = authorize(&ctx, req.alloc, req.lease)?;
        Ok((cfg, handle))
    })();
    let (cfg, handle) = match prep {
        Err(e) => {
            return write_frame(
                stream,
                &Response::failure(id, e).to_json(),
            )
        }
        Ok(v) => v,
    };
    let idx = handle
        .members()
        .iter()
        .position(|a| *a == req.alloc)
        .unwrap_or(0);
    write_frame(
        stream,
        &Response::stream_header(
            id,
            Json::obj(vec![
                ("core", Json::from(req.core.as_str())),
                ("binary", Json::from(binary)),
            ]),
        )
        .to_json(),
    )?;
    let mut seq = 0u64;
    let mut io_err: Option<std::io::Error> = None;
    let streamed =
        handle.stream_member_sink(idx, &cfg, &mut |chunk| {
            seq += 1;
            match write_data_frame(stream, binary, seq, chunk) {
                Ok(()) => true,
                Err(e) => {
                    io_err = Some(e);
                    false
                }
            }
        });
    if let Some(e) = io_err {
        return Err(e);
    }
    let term = match streamed {
        Ok(out) => {
            if binary {
                seq += 1;
                write_bin_frame(stream, &BinFrame::end_marker(seq))?;
            }
            StreamFrame::terminal_with_stats(
                seq + 1,
                None,
                StreamOutcomeBody::from_outcome(&out).to_json(),
            )
        }
        // A mid-stream failure lands on the terminal frame's error:
        // the header is already out, so the envelope cannot carry it.
        Err(e) => {
            StreamFrame::terminal(seq + 1, Some(ApiError::from(e)))
        }
    };
    write_frame(stream, &term.to_json())
}

/// Relay a data-plane stream from the owning node's daemon. The hop
/// request is stamped with the *end client's* protocol, so the
/// daemon emits exactly the framing the client negotiated and the
/// relay is a pure passthrough — binary frames are never inflated to
/// base64 on the proxy hop.
fn relay_stream_data(
    stream: &mut TcpStream,
    inner: &Arc<ServerInner>,
    cl: &Arc<crate::cluster::Coordinator>,
    proto: u32,
    id: Option<u64>,
    req: &StreamRequest,
) -> std::io::Result<()> {
    let dialed = (|| {
        let token = require_token(req.lease)?;
        let (_node, addr) = cl.agent_addr_of(token)?;
        let mut agent = TcpStream::connect(addr)
            .map_err(|e| ApiError::internal(e.to_string()))?;
        let areq = AgentStreamRequest {
            lease: token,
            alloc: req.alloc,
            core: req.core.clone(),
            mults: req.mults,
            emit_output: true,
        };
        let hop = Request {
            method: Method::AgentStream.name().to_string(),
            params: areq.to_json(),
            id: Some(1),
            proto: Some(proto),
            trace: None,
        };
        write_frame(&mut agent, &hop.to_json())
            .map_err(|e| ApiError::internal(e.to_string()))?;
        Ok(agent)
    })();
    let mut agent = match dialed {
        Err(e) => {
            return write_frame(
                stream,
                &Response::failure(id, e).to_json(),
            )
        }
        Ok(a) => a,
    };
    inner.hv.metrics.counter("cluster.stream_relay").inc();
    // First frame back is the header (or a single-frame failure);
    // rewrite its correlation id to the end client's.
    let header = match read_frame(&mut agent)? {
        Some(v) => v,
        None => {
            return write_frame(
                stream,
                &Response::failure(
                    id,
                    ApiError::internal(
                        "agent closed before stream header",
                    ),
                )
                .to_json(),
            )
        }
    };
    let header = match Response::from_json(&header) {
        Ok(mut r) => {
            r.id = id;
            r
        }
        Err(e) => Response::failure(id, ApiError::internal(e)),
    };
    let streaming = header.stream;
    write_frame(stream, &header.to_json())?;
    if !streaming {
        return Ok(());
    }
    let mut last_seq = 0u64;
    loop {
        let frame = match read_wire_frame(&mut agent)? {
            Some(f) => f,
            None => {
                // Node died mid-stream: close the client's stream
                // abnormally rather than hanging it.
                return write_frame(
                    stream,
                    &StreamFrame::terminal(
                        last_seq + 1,
                        Some(ApiError::internal(
                            "agent connection lost mid-stream",
                        )),
                    )
                    .to_json(),
                );
            }
        };
        match frame {
            WireFrame::Bin(b) => {
                last_seq = b.seq;
                write_bin_frame(stream, &b)?;
            }
            WireFrame::Json(v) => {
                last_seq = v.get("seq").as_u64().unwrap_or(last_seq);
                let end = v.get("end").as_bool().unwrap_or(false);
                write_frame(stream, &v)?;
                if end {
                    return Ok(());
                }
            }
        }
    }
}

/// Serve `agent.fetch_bitstream`: the artifact-transfer plane a node
/// daemon uses to pull a missing bitstream off the management cache
/// before programming (the caller is the *agent*; the management
/// server serves). A JSON header carries the lossless transfer
/// metadata with the payload out-of-band, then the payload bytes
/// follow as data frames — binary for protocol-4 callers, base64
/// `stream_data` events for protocol 3 — then a JSON terminal frame
/// whose stats carry the byte count and sha256 for the receiver to
/// verify reassembly against. A cache miss falls back to the
/// prebuilt core library so a cold cluster can still seed its nodes.
fn serve_fetch_bitstream(
    stream: &mut TcpStream,
    inner: &Arc<ServerInner>,
    proto: u32,
    id: Option<u64>,
    params: &Json,
) -> std::io::Result<()> {
    let binary = proto >= PROTO_DATA_FRAMES;
    let looked = (|| {
        if proto < 3 {
            return Err(ApiError::bad_request(
                "fetch_bitstream requires protocol 3",
            ));
        }
        let req = FetchBitstreamRequest::from_json(params)?;
        let bs = inner
            .cache
            .lookup_core(&req.core, &req.part)
            .or_else(|| inner.cores.get(&req.core).cloned())
            .ok_or_else(|| {
                ApiError::new(
                    ErrorCode::UnknownCore,
                    format!(
                        "no cached artifact or library core '{}'",
                        req.core
                    ),
                )
            })?;
        Ok((req, bs))
    })();
    let (req, bs) = match looked {
        Err(e) => {
            return write_frame(
                stream,
                &Response::failure(id, e).to_json(),
            )
        }
        Ok(found) => found,
    };
    if let (Some(cl), Some(node)) = (inner.cluster.as_ref(), req.node) {
        // A daemon identified itself: it now holds this artifact, so
        // placement can prefer it for future same-design admissions.
        cl.note_cached(node, &req.core);
    }
    inner.hv.metrics.counter("bitcache.fetch_served").inc();
    write_frame(
        stream,
        &Response::stream_header(id, bs.to_transfer_json(false))
            .to_json(),
    )?;
    let mut seq = 0u64;
    for chunk in bs.payload.chunks(FETCH_CHUNK) {
        seq += 1;
        write_data_frame(stream, binary, seq, chunk)?;
    }
    if binary {
        seq += 1;
        write_bin_frame(stream, &BinFrame::end_marker(seq))?;
    }
    let stats = Json::obj(vec![
        ("bytes", Json::from(bs.payload.len() as u64)),
        ("sha256", Json::from(bs.sha256.as_str())),
    ]);
    write_frame(
        stream,
        &StreamFrame::terminal_with_stats(seq + 1, None, stats)
            .to_json(),
    )
}

// ===================================================== dispatching

/// Per-request handler context. Every request that reaches a handler
/// already negotiated a supported protocol (2 or 3); the only
/// version-dependent behavior — `subscribe` being protocol-3-only —
/// is resolved before table dispatch, so handlers are
/// version-agnostic.
struct Ctx<'a> {
    inner: &'a Arc<ServerInner>,
}

type Handler = fn(&Ctx<'_>, &Json) -> Result<Json, ApiError>;

/// The dispatch table: one typed handler per management-server RPC.
/// `subscribe` is absent deliberately — its response is multi-frame
/// and is served by [`serve_subscription`] before table dispatch.
const HANDLERS: &[(Method, Handler)] = &[
    (Method::Hello, h_hello),
    (Method::AddUser, h_add_user),
    (Method::Status, h_status),
    (Method::AllocVfpga, h_alloc_vfpga),
    (Method::AllocPhysical, h_alloc_physical),
    (Method::Release, h_release),
    (Method::ProgramCore, h_program_core),
    (Method::Stream, h_stream),
    (Method::ProgramFull, h_program_full),
    (Method::Migrate, h_migrate),
    (Method::Services, h_services),
    (Method::InvokeService, h_invoke_service),
    (Method::Monitor, h_monitor),
    (Method::Workload, h_workload),
    (Method::SchedStatus, h_sched_status),
    (Method::QuotaSet, h_quota_set),
    (Method::QuotaGet, h_quota_get),
    (Method::UsageReport, h_usage_report),
    (Method::Reserve, h_reserve),
    (Method::CancelReservation, h_cancel_reservation),
    (Method::Energy, h_energy),
    (Method::DbDump, h_db_dump),
    (Method::Cores, h_cores),
    (Method::JobStatus, h_job_status),
    (Method::JobWait, h_job_wait),
    (Method::JobCancel, h_job_cancel),
    (Method::LifecycleLog, h_lifecycle_log),
    (Method::SchedPolicyGet, h_sched_policy_get),
    (Method::SchedPolicySet, h_sched_policy_set),
    (Method::MetricsExport, h_metrics_export),
    (Method::TraceGet, h_trace_get),
    (Method::CompileSubmit, h_compile_submit),
    (Method::CompileStatus, h_compile_status),
    (Method::NodeList, h_node_list),
    (Method::ClusterRegister, h_cluster_register),
];

/// Whether the management server serves `method` (dispatch-table
/// completeness is asserted by tests against [`Method::ALL`]).
/// `subscribe` and `agent.fetch_bitstream` are served out-of-table
/// (multi-frame responses).
pub fn method_is_served(method: Method) -> bool {
    method == Method::Subscribe
        || method == Method::AgentFetchBitstream
        || HANDLERS.iter().any(|(m, _)| *m == method)
}

fn dispatch(
    ctx: &Ctx<'_>,
    method: &str,
    params: &Json,
) -> Result<Json, ApiError> {
    let m = Method::parse(method)
        .ok_or_else(|| ApiError::unknown_method(method))?;
    let handler = HANDLERS
        .iter()
        .find(|(hm, _)| *hm == m)
        .map(|(_, h)| *h)
        .ok_or_else(|| ApiError::unknown_method(method))?;
    handler(ctx, params)
}

// ===================================================== capability auth

/// Capability check for mutating RPCs: resolve the allocation
/// (dead/foreign → `bad_lease` regardless of token), then require the
/// presented token to own it (`bad_token` when missing, forged or
/// stale). Returns the disarmed lease handle the handler should
/// operate through — its tenant, not the wire `user` field, is the
/// authorized identity.
fn authorize(
    ctx: &Ctx<'_>,
    alloc: AllocationId,
    lease: Option<LeaseToken>,
) -> Result<Lease, ApiError> {
    let grant = ctx.inner.sched.grant(alloc).ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadLease,
            format!("no scheduler grant for {alloc}"),
        )
    })?;
    let token = lease.ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadToken,
            "mutating calls require the lease token",
        )
    })?;
    if grant.token != token {
        return Err(ApiError::new(
            ErrorCode::BadToken,
            format!("lease token does not own {alloc}"),
        ));
    }
    // A concurrent release between the grant check and here reads as
    // a stale token, not a server panic.
    ctx.inner.sched.lease_handle(token).ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadToken,
            "lease released mid-request".to_string(),
        )
    })
}

/// Owner gate for `job_*` RPCs: an owned job only answers to the
/// token that submitted it.
fn authorize_job(
    owner: Option<LeaseToken>,
    presented: Option<LeaseToken>,
) -> Result<(), ApiError> {
    match owner {
        Some(t) if presented != Some(t) => Err(ApiError::new(
            ErrorCode::BadToken,
            "job is owned by a different lease token",
        )),
        _ => Ok(()),
    }
}

// ========================================================= handlers

fn h_hello(_ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = HelloRequest::from_json(p)?;
    let chosen = req.negotiate().ok_or_else(|| {
        ApiError::protocol_mismatch(req.proto_min, req.proto_max)
    })?;
    Ok(HelloResponse {
        version: crate::VERSION.to_string(),
        service: "rc3e-management".to_string(),
        proto_min: PROTO_MIN,
        proto_max: PROTO_MAX,
        proto: chosen,
    }
    .to_json())
}

fn h_add_user(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = AddUserRequest::from_json(p)?;
    let user = ctx.inner.hv.add_user(&req.name);
    Ok(AddUserResponse { user }.to_json())
}

fn h_status(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = StatusRequest::from_json(p)?;
    let inner = ctx.inner;
    // Route via the owning node's agent when registered.
    let node = inner.hv.device(req.fpga).map_err(ApiError::from)?.node;
    let agent_addr = inner.agents.lock().unwrap().get(&node).copied();
    let resp = if let Some(addr) = agent_addr {
        let mut agent =
            Client::connect(addr).map_err(ApiError::internal)?;
        agent.agent_status(req.fpga)?
    } else {
        let st = inner
            .hv
            .status_local(req.fpga)
            .map_err(ApiError::from)?;
        StatusResponse::from_status(&st)
    };
    Ok(resp.to_json())
}

fn h_alloc_vfpga(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = AllocVfpgaRequest::from_json(p)?;
    let model = req.model.unwrap_or(ServiceModel::RAaaS);
    if model == ServiceModel::RSaaS {
        return Err(ApiError::bad_request(
            "alloc_vfpga serves vFPGA models; use alloc_physical for \
             RSaaS",
        ));
    }
    let class = req.class.unwrap_or(RequestClass::Interactive);
    if let Some(core) = &req.core {
        // Prefetch hint, never a constraint: remember the intended
        // core so a queue wait warms the cache for this tenant.
        ctx.inner.prefetch.note_core(req.user, core);
    }
    if let Some(cl) = &ctx.inner.cluster {
        // Federated: route the admission across registered node
        // daemons. Tenants cross the node boundary by *name* (each
        // process keeps its own id space), so the wire `user` must
        // already exist here (`add_user`).
        let tenant = ctx
            .inner
            .hv
            .db
            .lock()
            .unwrap()
            .user_name(req.user)
            .map(|n| n.to_string())
            .ok_or_else(|| {
                ApiError::bad_request(format!(
                    "unknown user {} (add_user first)",
                    req.user
                ))
            })?;
        let resp = cl.admit_remote(&AgentAdmitRequest {
            tenant,
            model: Some(model),
            class: Some(class),
            regions: req.regions,
            co_located: req.co_located,
            board: req.board.clone(),
            core: req.core.clone(),
            adopt: None,
        })?;
        return Ok(resp.to_json());
    }
    let mut areq = AdmissionRequest::new(req.user, model, class);
    if let Some(n) = req.regions {
        areq = areq.gang(n);
    }
    if req.co_located == Some(true) {
        areq = areq.co_located();
    }
    if let Some(b) = &req.board {
        let board = BoardKind::parse(b).ok_or_else(|| {
            ApiError::bad_request(format!("unknown board '{b}'"))
        })?;
        areq = areq.on_board(board);
    }
    let lease = ctx.inner.sched.admit(&areq).map_err(ApiError::from)?;
    let members: Vec<GangMemberBody> = lease
        .placements()
        .iter()
        .map(|pl| GangMemberBody {
            alloc: pl.alloc,
            vfpga: match pl.target {
                crate::sched::GrantTarget::Vfpga(v, _, _) => v,
                crate::sched::GrantTarget::Physical(_, _) => {
                    unreachable!("vFPGA admission")
                }
            },
            fpga: match pl.target {
                crate::sched::GrantTarget::Vfpga(_, f, _)
                | crate::sched::GrantTarget::Physical(f, _) => f,
            },
            node: match pl.target {
                crate::sched::GrantTarget::Vfpga(_, _, n)
                | crate::sched::GrantTarget::Physical(_, n) => n,
            },
        })
        .collect();
    let primary = members.first().cloned().ok_or_else(|| {
        ApiError::internal("admitted lease has no members")
    })?;
    let resp = AllocVfpgaResponse {
        alloc: primary.alloc,
        vfpga: primary.vfpga,
        fpga: primary.fpga,
        node: primary.node,
        wait_ms: lease.wait().as_millis_f64(),
        lease: lease.token(),
        members,
    };
    // Disarm: the lease stays live server-side, owned by whoever
    // holds the token.
    let _token = lease.into_token();
    Ok(resp.to_json())
}

fn h_alloc_physical(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = AllocPhysicalRequest::from_json(p)?;
    let lease = ctx
        .inner
        .sched
        .admit(&AdmissionRequest::physical(
            req.user,
            RequestClass::Interactive,
        ))
        .map_err(ApiError::from)?;
    let resp = AllocPhysicalResponse {
        alloc: lease.alloc(),
        fpga: lease.fpga().ok_or_else(|| {
            ApiError::internal("fresh physical lease has no placement")
        })?,
        node: lease.node().ok_or_else(|| {
            ApiError::internal("fresh physical lease has no placement")
        })?,
        lease: lease.token(),
    };
    let _token = lease.into_token();
    Ok(resp.to_json())
}

fn h_release(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = ReleaseRequest::from_json(p)?;
    if let Some(cl) = &ctx.inner.cluster {
        // Federated: the token names the lease cluster-wide; release
        // it on whichever node it is homed.
        let token = require_token(req.lease)?;
        let mut client = dial_home(cl, token)?;
        let resp = client.agent_release(token)?;
        cl.forget(token);
        return Ok(resp.to_json());
    }
    // The capability releases the *whole* lease (every gang member),
    // like Lease::release everywhere else.
    let handle = authorize(ctx, req.alloc, req.lease)?;
    handle.release().map_err(ApiError::from)?;
    Ok(ReleaseResponse { released: true }.to_json())
}

/// Federated handlers authorize by token presence + home lookup; the
/// owning node's scheduler does the actual fencing.
fn require_token(
    lease: Option<LeaseToken>,
) -> Result<LeaseToken, ApiError> {
    lease.ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadToken,
            "mutating calls require the lease token",
        )
    })
}

/// Connect to the node a federated lease is homed on.
fn dial_home(
    cl: &Arc<crate::cluster::Coordinator>,
    token: LeaseToken,
) -> Result<Client, ApiError> {
    let node = cl.home_of(token).ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadToken,
            "no federated lease for this token",
        )
    })?;
    let addr = cl.registry().addr_of(node).ok_or_else(|| {
        ApiError::internal(format!("lease home {node} not registered"))
    })?;
    Client::connect(addr).map_err(ApiError::internal)
}

fn h_program_core(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = ProgramCoreRequest::from_json(p)?;
    if let Some(cl) = &ctx.inner.cluster {
        let token = require_token(req.lease)?;
        let mut client = dial_home(cl, token)?;
        let resp = client.agent_program(&AgentProgramRequest {
            lease: token,
            alloc: req.alloc,
            core: req.core,
        })?;
        return Ok(resp.to_json());
    }
    // The token's tenant is the authorized identity — the wire `user`
    // field is not trusted.
    let handle = authorize(ctx, req.alloc, req.lease)?;
    let user = handle.tenant();
    let inner = ctx.inner;
    inner.prefetch.note_core(user, &req.core);
    // Warm tier first: an AOT artifact in the cache programs without
    // any compile (`bitcache.hit`); a miss (`bitcache.miss`) falls
    // back to the prebuilt library. The resident tier below both —
    // region already holding this exact design — is the hypervisor's
    // call (`bitcache.resident_skip`).
    let cached = {
        let part = handle
            .fpga()
            .and_then(|f| {
                let db = inner.hv.db.lock().unwrap();
                db.device(f).map(|d| BoardSpec::of(d.board).part)
            })
            .unwrap_or(BoardSpec::vc707().part);
        inner.cache.lookup_core(&req.core, part)
    };
    let bitfile = match &cached {
        Some(bs) => bs,
        None => inner.cores.get(&req.core).ok_or_else(|| {
            ApiError::new(
                ErrorCode::UnknownCore,
                format!("unknown core '{}'", req.core),
            )
        })?,
    };
    // Retarget + PR under one region pin: a relocation cannot slip
    // between placement resolution and programming.
    let d = inner
        .hv
        .program_retargeted(req.alloc, user, bitfile)
        .map_err(ApiError::from)?;
    Ok(ProgramCoreResponse {
        programmed: req.core,
        pr_ms: d.as_millis_f64(),
    }
    .to_json())
}

fn h_stream(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let mut req = StreamRequest::from_json(p)?;
    if let Some(cl) = &ctx.inner.cluster {
        // Federated: same async-job surface, but the worker streams
        // on the owning node (synchronously over the agent wire) and
        // relays the outcome.
        let token = require_token(req.lease)?;
        let (_node, addr) = cl.agent_addr_of(token)?;
        let areq = AgentStreamRequest {
            lease: token,
            alloc: req.alloc,
            core: req.core.clone(),
            mults: req.mults,
            emit_output: false,
        };
        let owner = req.lease;
        let now_ns = ctx.inner.hv.clock.now().0;
        let job = Arc::clone(&ctx.inner.jobs).submit(
            Method::Stream.name(),
            now_ns,
            owner,
            move |progress| {
                progress.report("dial", 0, 5.0);
                let mut client =
                    Client::connect(addr).map_err(ApiError::internal)?;
                progress.report("streaming", 0, 25.0);
                let out = client.agent_stream(&areq)?;
                progress.report("streamed", out.output_bytes, 90.0);
                Ok(out.to_json())
            },
        );
        return Ok(JobSubmitResponse { job, lease: owner }.to_json());
    }
    let handle = authorize(ctx, req.alloc, req.lease)?;
    req.user = handle.tenant();
    let owner = req.lease;
    let inner = Arc::clone(ctx.inner);
    let now_ns = ctx.inner.hv.clock.now().0;
    let job = Arc::clone(&ctx.inner.jobs).submit(
        Method::Stream.name(),
        now_ns,
        owner,
        move |progress| run_stream(&inner, &req, progress),
    );
    Ok(JobSubmitResponse { job, lease: owner }.to_json())
}

fn h_program_full(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let mut req = ProgramFullRequest::from_json(p)?;
    let handle = authorize(ctx, req.alloc, req.lease)?;
    req.user = handle.tenant();
    let owner = req.lease;
    let inner = Arc::clone(ctx.inner);
    let now_ns = ctx.inner.hv.clock.now().0;
    let job = Arc::clone(&ctx.inner.jobs).submit(
        Method::ProgramFull.name(),
        now_ns,
        owner,
        move |progress| run_program_full(&inner, &req, progress),
    );
    Ok(JobSubmitResponse { job, lease: owner }.to_json())
}

fn h_invoke_service(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = InvokeServiceRequest::from_json(p)?;
    // No lease is involved (BAaaS allocates internally); mint a
    // job-scoped owner token so the job handle is still a capability,
    // not an enumerable id anyone can cancel.
    let owner = LeaseToken::mint();
    let inner = Arc::clone(ctx.inner);
    let now_ns = ctx.inner.hv.clock.now().0;
    let job = Arc::clone(&ctx.inner.jobs).submit(
        Method::InvokeService.name(),
        now_ns,
        Some(owner),
        move |progress| run_invoke_service(&inner, &req, progress),
    );
    Ok(JobSubmitResponse {
        job,
        lease: Some(owner),
    }
    .to_json())
}

fn h_migrate(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = MigrateRequest::from_json(p)?;
    let handle = authorize(ctx, req.alloc, req.lease)?;
    let user = handle.tenant();
    // Default target selection is model-aware (see
    // hypervisor::migration), so the relocated lease stays within the
    // per-device model policy.
    let report = ctx
        .inner
        .hv
        .migrate_vfpga(req.alloc, user, None)
        .map_err(ApiError::from)?;
    // Keep the scheduler's view of the lease current so preemption
    // victim selection and sched_status stay accurate (this also
    // publishes the tenant's LeasePlacementChanged event).
    ctx.inner.sched.note_migration(req.alloc, report.to);
    Ok(MigrateResponse {
        from: report.from,
        to: report.to,
        cross_device: report.moved_across_devices,
        downtime_ms: report.downtime.as_millis_f64(),
    }
    .to_json())
}

fn h_services(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = ServicesRequest::from_json(p)?;
    Ok(ServicesResponse {
        services: ctx.inner.hv.service_names(),
    }
    .to_json())
}

fn h_cores(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = CoresRequest::from_json(p)?;
    Ok(CoresResponse {
        cores: ctx.inner.cores.keys().cloned().collect(),
    }
    .to_json())
}

fn h_monitor(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = MonitorRequest::from_json(p)?;
    let hv = &ctx.inner.hv;
    // One monitoring sweep over every device + report, plus the
    // scheduler's admission telemetry (the `sched.wait` histogram and
    // queue-depth gauge over the wire) and the region-lifecycle
    // telemetry (per-state occupancy gauges, quiesce-wait histogram,
    // raced counter).
    let mut mon = crate::hypervisor::Monitor::new();
    mon.sample_all(hv);
    hv.refresh_region_gauges();
    let wait = hv.metrics.histogram("sched.wait");
    let quiesce_wait =
        hv.metrics.histogram("sched.preempt.quiesce_wait");
    let state_gauge =
        |name: &str| hv.metrics.gauge(&format!("region.state.{name}")).get();
    Ok(MonitorResponse {
        devices: mon.to_json(),
        cloud_utilization: mon.cloud_utilization(),
        sched: SchedTelemetry {
            queue_depth: hv.metrics.gauge("sched.queue.depth").get(),
            active_grants: hv
                .metrics
                .gauge("sched.active_grants")
                .get(),
            wait: WaitStats::from_histogram(&wait),
            quiesce_wait: WaitStats::from_histogram(&quiesce_wait),
            preempt_raced: hv
                .metrics
                .counter("sched.preempt.raced")
                .get(),
            lifecycle: LifecycleOccupancy {
                free: state_gauge("free"),
                reserved: state_gauge("reserved"),
                programming: state_gauge("programming"),
                active: state_gauge("active"),
                draining: state_gauge("draining"),
                migrating: state_gauge("migrating"),
            },
        },
    }
    .to_json())
}

fn h_workload(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = WorkloadRequest::from_json(p)?;
    // Run a synthetic session workload (operator tooling / capacity
    // planning).
    let w = crate::hypervisor::CloudWorkload {
        arrival_rate: req.rate.unwrap_or(0.05),
        mean_hold_s: req.hold_s.unwrap_or(120.0),
        sessions: req.sessions.unwrap_or(40) as usize,
        seed: req.seed.unwrap_or(0x10AD),
    };
    let report = crate::hypervisor::workload::run(&ctx.inner.hv, &w)
        .map_err(|e| ApiError::internal(e.to_string()))?;
    Ok(WorkloadResponse {
        served: report.served as u64,
        rejected: report.rejected as u64,
        admission_rate: report.admission_rate(),
        mean_setup_ms: report.mean_setup_ms,
        mean_utilization: report.mean_utilization,
        makespan_s: report.makespan.as_secs_f64(),
        energy_j: report.energy_j,
    }
    .to_json())
}

fn h_sched_status(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = SchedStatusRequest::from_json(p)?;
    Ok(SchedStatusResponse {
        status: ctx.inner.sched.status_json(),
    }
    .to_json())
}

fn h_quota_set(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = QuotaSetRequest::from_json(p)?;
    // Absent fields keep their current values; `max_vfpgas: 0`
    // restores an unlimited cap and a negative `budget_s` clears the
    // budget (the JSON layer cannot distinguish null from absent).
    // The merge runs atomically under the scheduler's lock so
    // concurrent partial updates cannot lose each other's fields.
    let quota = ctx.inner.sched.update_quota(req.user, |q| {
        match req.max_vfpgas {
            Some(0) => q.max_concurrent = u64::MAX,
            Some(n) => q.max_concurrent = n,
            None => {}
        }
        match req.budget_s {
            Some(b) if b < 0.0 => q.device_seconds_budget = None,
            Some(b) => q.device_seconds_budget = Some(b),
            None => {}
        }
        if let Some(w) = req.weight {
            q.weight = w.max(1);
        }
    });
    Ok(QuotaResponse::from_quota(
        req.user,
        &quota,
        ctx.inner.sched.in_use(req.user),
    )
    .to_json())
}

fn h_quota_get(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = QuotaGetRequest::from_json(p)?;
    let quota = ctx.inner.sched.quota(req.user);
    Ok(QuotaResponse::from_quota(
        req.user,
        &quota,
        ctx.inner.sched.in_use(req.user),
    )
    .to_json())
}

fn h_usage_report(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = UsageReportRequest::from_json(p)?;
    Ok(UsageReportResponse {
        tenants: ctx.inner.sched.usage_json(),
        table: ctx.inner.sched.usage_report(),
    }
    .to_json())
}

fn h_reserve(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = ReserveRequest::from_json(p)?;
    let start_s = req
        .start_s
        .unwrap_or_else(|| ctx.inner.hv.clock.now().as_secs_f64());
    let duration_s = req.duration_s.unwrap_or(3600.0);
    let reservation = ctx.inner.sched.reserve(
        req.user,
        req.regions,
        req.model,
        VirtualTime::from_secs_f64(start_s),
        VirtualTime::from_secs_f64(duration_s),
    );
    Ok(ReserveResponse { reservation }.to_json())
}

fn h_cancel_reservation(
    ctx: &Ctx<'_>,
    p: &Json,
) -> Result<Json, ApiError> {
    let req = CancelReservationRequest::from_json(p)?;
    ctx.inner
        .sched
        .cancel_reservation(req.reservation)
        .map_err(ApiError::from)?;
    Ok(CancelReservationResponse { cancelled: true }.to_json())
}

fn h_energy(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = EnergyRequest::from_json(p)?;
    Ok(EnergyResponse {
        joules: ctx.inner.hv.total_energy_joules(),
        power_w: ctx.inner.hv.total_power_w(),
    }
    .to_json())
}

fn h_db_dump(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = DbDumpRequest::from_json(p)?;
    Ok(DbDumpResponse {
        db: ctx.inner.hv.db.lock().unwrap().to_json(),
    }
    .to_json())
}

fn h_lifecycle_log(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = LifecycleLogRequest::from_json(p)?;
    let dev = ctx.inner.hv.device(req.fpga).map_err(ApiError::from)?;
    let (records, dropped) = {
        let fpga = dev.fpga.lock().unwrap();
        (fpga.transition_log(), fpga.transition_log_dropped())
    };
    let limit = req.limit.unwrap_or(u64::MAX) as usize;
    let skip = records.len().saturating_sub(limit);
    let records: Vec<TransitionBody> = records[skip..]
        .iter()
        .map(|r| TransitionBody {
            region: r.region,
            from: r.from.name().to_string(),
            to: r.to.name().to_string(),
            at_s: r.at.as_secs_f64(),
        })
        .collect();
    Ok(LifecycleLogResponse {
        fpga: req.fpga,
        records,
        dropped,
    }
    .to_json())
}

fn h_sched_policy_get(
    ctx: &Ctx<'_>,
    p: &Json,
) -> Result<Json, ApiError> {
    let _req = SchedPolicyGetRequest::from_json(p)?;
    Ok(SchedPolicyResponse {
        policy: ctx.inner.sched.preempt_policy().name().to_string(),
    }
    .to_json())
}

fn h_sched_policy_set(
    ctx: &Ctx<'_>,
    p: &Json,
) -> Result<Json, ApiError> {
    let req = SchedPolicySetRequest::from_json(p)?;
    let policy = PreemptPolicy::parse(&req.policy).ok_or_else(|| {
        ApiError::bad_request(format!(
            "unknown policy '{}' (spread|pack)",
            req.policy
        ))
    })?;
    ctx.inner.sched.set_preempt_policy(policy);
    Ok(SchedPolicyResponse {
        policy: policy.name().to_string(),
    }
    .to_json())
}

fn h_metrics_export(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = MetricsExportRequest::from_json(p)?;
    // Freshen the derived gauges so the export is a consistent view,
    // like `monitor` does before reading them.
    ctx.inner.hv.refresh_region_gauges();
    let snap = ctx.inner.hv.metrics.snapshot();
    Ok(MetricsExportResponse::from_snapshot(&snap).to_json())
}

fn h_compile_submit(
    ctx: &Ctx<'_>,
    p: &Json,
) -> Result<Json, ApiError> {
    let req = CompileSubmitRequest::from_json(p)?;
    let part = req
        .part
        .clone()
        .unwrap_or_else(|| BoardSpec::vc707().part.to_string());
    // Remember the ask: a later queued admission from this tenant
    // prefetches the same core.
    ctx.inner.prefetch.note_core(req.user, &req.core);
    let ticket = ctx.inner.compiler.submit(&req.core, &part)?;
    Ok(CompileSubmitResponse {
        digest: ticket.digest,
        state: ticket.state.to_string(),
        job: ticket.job,
        lease: ticket.token,
    }
    .to_json())
}

fn h_compile_status(
    ctx: &Ctx<'_>,
    p: &Json,
) -> Result<Json, ApiError> {
    let req = CompileStatusRequest::from_json(p)?;
    let ticket = ctx.inner.compiler.status(&req.digest);
    Ok(CompileStatusResponse {
        digest: ticket.digest,
        state: ticket.state.to_string(),
        job: ticket.job,
    }
    .to_json())
}

fn h_trace_get(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = TraceGetRequest::from_json(p)?;
    let trace = match (req.trace, req.job) {
        (Some(t), _) => t,
        (None, Some(job)) => {
            // Resolve through the job registry: the record carries the
            // submitting RPC's trace id.
            let rec = ctx.inner.jobs.status(job)?;
            rec.trace.ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{job} carries no trace (tracing was off at submit)"
                ))
            })?
        }
        // from_json enforces exactly one selector.
        (None, None) => unreachable!("validated by from_json"),
    };
    let snap = ctx.inner.tracer.snapshot(trace).ok_or_else(|| {
        ApiError::bad_request(format!(
            "unknown trace {trace} (never recorded, or evicted from \
             the flight recorder)"
        ))
    })?;
    Ok(TraceGetResponse::from_snapshot(&snap).to_json())
}

fn h_node_list(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let _req = NodeListRequest::from_json(p)?;
    let nodes = if let Some(cl) = &ctx.inner.cluster {
        crate::cluster::federation::nodes_body(
            &cl.registry().snapshot(),
        )
    } else {
        // Single-process topology: synthesize entries from the
        // registered status agents and the shared device DB. These
        // agents share the server's hypervisor, so they are `up` by
        // construction and their vitals are read directly.
        let agents = ctx.inner.agents.lock().unwrap().clone();
        let mut nodes = Vec::new();
        for (node, addr) in agents {
            let mut boards = std::collections::BTreeSet::new();
            let mut free = 0u64;
            let mut total = 0u64;
            {
                let db = ctx.inner.hv.db.lock().unwrap();
                for f in ctx.inner.hv.device_ids() {
                    let Some(d) = db.device(f) else { continue };
                    if d.node != node {
                        continue;
                    }
                    boards.insert(d.board.name().to_string());
                    free += db.free_regions(f).len() as u64;
                    total += d.regions.len() as u64;
                }
            }
            let leases = ctx
                .inner
                .sched
                .live_tokens()
                .into_iter()
                .filter(|t| {
                    ctx.inner
                        .sched
                        .lease_handle(*t)
                        .and_then(|h| h.node())
                        == Some(node)
                })
                .count() as u64;
            nodes.push(NodeBody {
                node,
                addr: addr.to_string(),
                boards: boards.into_iter().collect(),
                regions_free: free,
                regions_active: total - free,
                leases,
                heartbeat_age_ms: 0.0,
                state: "up".to_string(),
            });
        }
        nodes
    };
    Ok(NodeListResponse { nodes }.to_json())
}

fn h_cluster_register(
    ctx: &Ctx<'_>,
    p: &Json,
) -> Result<Json, ApiError> {
    let req = ClusterRegisterRequest::from_json(p)?;
    let cl = ctx.inner.cluster.as_ref().ok_or_else(|| {
        ApiError::bad_request(
            "server is not federated (start with --federated)",
        )
    })?;
    log::info!(
        "cluster.register: {} ({}) at {} with {} boards, {} leases",
        req.node,
        req.name,
        req.addr,
        req.boards.len(),
        req.tokens.len()
    );
    let resp = cl.register(&req)?;
    Ok(resp.to_json())
}

fn h_job_status(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = JobStatusRequest::from_json(p)?;
    let rec = ctx.inner.jobs.status(req.job)?;
    authorize_job(rec.owner, req.lease)?;
    Ok(rec.to_body().to_json())
}

fn h_job_wait(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = JobWaitRequest::from_json(p)?;
    // Gate on ownership *before* blocking — a forged token must not
    // be able to park threads on someone else's job.
    let rec = ctx.inner.jobs.status(req.job)?;
    authorize_job(rec.owner, req.lease)?;
    // Cap below the client library's 120 s socket read timeout: a
    // server-side wait that outlives the client's read would leave a
    // stale frame on the connection and desynchronize every later
    // response. Clients long-poll by retrying on `timeout` instead
    // (see Client::job_wait_done). All callers parked on one job
    // share a coalescing slot — one completion fanout wakes them all.
    let timeout_s = req
        .timeout_s
        .unwrap_or(DEFAULT_WAIT_S)
        .clamp(0.01, MAX_WAIT_S);
    let rec = ctx
        .inner
        .jobs
        .wait(req.job, Duration::from_secs_f64(timeout_s))?;
    Ok(rec.to_body().to_json())
}

fn h_job_cancel(ctx: &Ctx<'_>, p: &Json) -> Result<Json, ApiError> {
    let req = JobCancelRequest::from_json(p)?;
    let rec = ctx.inner.jobs.status(req.job)?;
    authorize_job(rec.owner, req.lease)?;
    Ok(ctx.inner.jobs.cancel(req.job)?.to_body().to_json())
}

// ====================================== long-running operation bodies
//
// Each worker emits JobProgress frames at its phase boundaries and
// stream checkpoints; the registry adds the `submitted` and terminal
// frames around them.

pub(crate) fn stream_config_for(
    core: &str,
    mults: u64,
) -> Result<StreamConfig, ApiError> {
    match core {
        "matmul16" => Ok(StreamConfig::matmul16(mults)),
        "matmul32" => Ok(StreamConfig::matmul32(mults)),
        c => Err(ApiError::new(
            ErrorCode::UnknownCore,
            format!("no stream profile for core '{c}'"),
        )),
    }
}

fn run_stream(
    inner: &ServerInner,
    req: &StreamRequest,
    progress: &ProgressReporter,
) -> Result<Json, ApiError> {
    progress.report("resolve", 0, 5.0);
    let cfg = stream_config_for(&req.core, req.mults)?;
    // Recover the lease handle from the grant so the session-open +
    // streaming body lives in exactly one place: Lease::stream. The
    // handle resolves placement at run time — a migration between
    // submit and run streams through the new device.
    let grant = inner.sched.grant(req.alloc).ok_or_else(|| {
        ApiError::from(HypervisorError::BadAllocation(req.alloc))
    })?;
    if grant.user != req.user {
        return Err(ApiError::from(HypervisorError::BadAllocation(
            req.alloc,
        )));
    }
    let handle = inner.sched.lease_handle(grant.token).ok_or_else(|| {
        ApiError::from(HypervisorError::BadAllocation(req.alloc))
    })?;
    // Stream the *requested* member (gang leases share one token).
    let idx = handle
        .members()
        .iter()
        .position(|a| *a == req.alloc)
        .unwrap_or(0);
    progress.report("streaming", 0, 25.0);
    let out = handle.stream_member(idx, &cfg).map_err(ApiError::from)?;
    // Stream checkpoint: bytes are known once the session closes.
    progress.report("streamed", out.output_bytes, 90.0);
    Ok(StreamOutcomeBody::from_outcome(&out).to_json())
}

fn run_program_full(
    inner: &ServerInner,
    req: &ProgramFullRequest,
    progress: &ProgressReporter,
) -> Result<Json, ApiError> {
    // RSaaS: write a full user bitstream to an exclusively held
    // device (server builds the synthetic image; a real deployment
    // would receive an upload).
    progress.report("build_bitstream", 0, 10.0);
    let name = req
        .name
        .clone()
        .unwrap_or_else(|| "user_design".to_string());
    let fpga = {
        let db = inner.hv.db.lock().unwrap();
        db.allocations
            .get(&req.alloc)
            .and_then(|a| match a.kind {
                AllocKind::Physical(f) | AllocKind::Vm(_, f) => Some(f),
                _ => None,
            })
            .ok_or_else(|| {
                ApiError::new(
                    ErrorCode::BadLease,
                    format!("allocation {} is not physical", req.alloc),
                )
            })?
    };
    let part = inner
        .hv
        .device(fpga)
        .map_err(ApiError::from)?
        .fpga
        .lock()
        .unwrap()
        .board
        .part;
    let bs =
        crate::bitstream::BitstreamBuilder::full(part, &name).build();
    progress.report("configuring", 0, 40.0);
    let d = inner
        .hv
        .program_full(req.alloc, req.user, &bs)
        .map_err(ApiError::from)?;
    progress.report("configured", 0, 95.0);
    Ok(ProgramFullResponse {
        programmed: name,
        config_s: d.as_secs_f64(),
    }
    .to_json())
}

fn run_invoke_service(
    inner: &ServerInner,
    req: &InvokeServiceRequest,
    progress: &ProgressReporter,
) -> Result<Json, ApiError> {
    let core = if req.service.contains("32") {
        "matmul32"
    } else {
        "matmul16"
    };
    let cfg = stream_config_for(core, req.mults)?;
    progress.report("admitting", 0, 10.0);
    let svc = crate::service::BaaasService::with_scheduler(Arc::clone(
        &inner.sched,
    ));
    progress.report("streaming", 0, 40.0);
    let out = svc
        .invoke(req.user, &req.service, &cfg)
        .map_err(ApiError::from)?;
    Ok(StreamOutcomeBody::from_outcome(&out).to_json())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;
    use crate::util::ids::{FpgaId, JobId};

    fn setup() -> (ManagementServer, Client, Arc<Hypervisor>) {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        let server = ManagementServer::spawn(Arc::clone(&hv), 69.0).unwrap();
        let client = Client::connect(server.addr()).unwrap();
        (server, client, hv)
    }

    #[test]
    fn dispatch_table_covers_every_management_method() {
        for m in Method::ALL {
            assert_eq!(
                method_is_served(m),
                !m.is_agent(),
                "dispatch entry mismatch for {}",
                m.name()
            );
        }
    }

    #[test]
    fn hello_and_cores() {
        let (_s, mut c, _hv) = setup();
        let hello = c.hello().unwrap();
        assert_eq!(hello.version, crate::VERSION);
        // The server advertises its protocol window.
        assert_eq!(hello.proto_min, PROTO_MIN);
        assert_eq!(hello.proto_max, PROTO_MAX);
        let cores = c.cores().unwrap();
        assert!(cores.cores.contains(&"matmul16".to_string()));
    }

    #[test]
    fn protoless_requests_are_rejected_as_protocol_1() {
        let (s, _c, _hv) = setup();
        let mut stream = TcpStream::connect(s.addr()).unwrap();
        // A protocol-1 request: no `proto`, no `id`.
        let raw = Json::obj(vec![
            ("method", Json::from("hello")),
            ("params", Json::obj(vec![])),
        ]);
        write_frame(&mut stream, &raw).unwrap();
        let frame = read_frame(&mut stream).unwrap().unwrap();
        let resp = Response::from_json(&frame).unwrap();
        let err = resp.into_api_result().unwrap_err();
        assert_eq!(err.code, ErrorCode::ProtocolMismatch);
    }

    #[test]
    fn status_over_rc3e_costs_80ms() {
        let (_s, mut c, hv) = setup();
        let t0 = hv.clock.now();
        let st = c.status(FpgaId(0)).unwrap();
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!(
            (ms - crate::paper::STATUS_RC3E_MS).abs() < 0.5,
            "status over RC3E took {ms} ms"
        );
        assert_eq!(st.regions_total, 4);
    }

    #[test]
    fn status_routes_through_registered_agent() {
        let (s, mut c, hv) = setup();
        let agent = super::super::agent::NodeAgent::spawn(
            Arc::clone(&hv),
            NodeId(0),
            None,
        )
        .unwrap();
        s.register_agent(NodeId(0), agent.addr());
        let t0 = hv.clock.now();
        let st = c.status(FpgaId(0)).unwrap();
        assert_eq!(st.board, "vc707");
        // Same virtual cost as the unrouted path (Table I: local vs
        // remote node over RC3E are both 80 ms).
        let ms = hv.clock.since(t0).as_millis_f64();
        assert!((ms - 80.0).abs() < 0.5, "{ms}");
    }

    #[test]
    fn full_lease_cycle_over_rpc() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("cli").unwrap().user;
        let lease = c.alloc_vfpga(user, None, None).unwrap();
        let prog =
            c.program_core(user, lease.alloc, "matmul16").unwrap();
        // PR over RC3E ≈ 732 + 111 (orchestration); the RPC hop is
        // charged before dispatch.
        assert!((prog.pr_ms - 843.0).abs() < 1.0, "{}", prog.pr_ms);
        assert!(c.release(lease.alloc).unwrap().released);
    }

    #[test]
    fn stream_over_rpc_returns_outcome() {
        if !crate::testing::artifacts_available(
            "middleware::stream_over_rpc_returns_outcome",
        ) {
            return;
        }
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("u").unwrap().user;
        let lease = c.alloc_vfpga(user, None, None).unwrap();
        c.program_core(user, lease.alloc, "matmul16").unwrap();
        let out =
            c.stream_sync(user, lease.alloc, "matmul16", 512).unwrap();
        assert_eq!(out.validation_failures, 0);
        assert!(out.virtual_mbps > 400.0);
    }

    #[test]
    fn errors_are_application_level() {
        let (_s, mut c, _hv) = setup();
        // Unknown method.
        let err =
            c.call_v2("reboot_world", Json::obj(vec![])).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownMethod);
        // Bad params.
        let err = c
            .call_v2(
                "status",
                Json::obj(vec![("fpga", Json::from("x"))]),
            )
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // Connection survives both errors.
        assert!(c.hello().is_ok());
    }

    #[test]
    fn db_dump_is_valid_json_db() {
        let (_s, mut c, _hv) = setup();
        let dump = c.db_dump().unwrap();
        let db = crate::hypervisor::DeviceDb::from_json(&dump.db).unwrap();
        assert_eq!(db.devices.len(), 4);
    }

    #[test]
    fn quota_rpcs_roundtrip_and_enforce() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("q").unwrap().user;
        let set = c
            .quota_set(&QuotaSetRequest {
                user,
                max_vfpgas: Some(1),
                budget_s: None,
                weight: Some(3),
            })
            .unwrap();
        assert_eq!(set.max_vfpgas, 1);
        let got = c.quota_get(user).unwrap();
        assert_eq!(got.weight, 3);
        // First lease fits the quota; the second is denied.
        c.alloc_vfpga(user, None, None).unwrap();
        let err = c.alloc_vfpga(user, None, None).unwrap_err();
        assert_eq!(err.code, ErrorCode::QuotaExceeded);
    }

    #[test]
    fn sched_status_and_usage_rpcs() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("u").unwrap().user;
        let lease = c.alloc_vfpga(user, None, None).unwrap();
        let status = c.sched_status().unwrap();
        assert_eq!(
            status.status.get("active_grants").as_u64(),
            Some(1)
        );
        assert_eq!(status.status.get("queue_depth").as_u64(), Some(0));
        c.release(lease.alloc).unwrap();
        let usage = c.usage_report().unwrap();
        let tenants = usage.tenants.as_arr().unwrap();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].get("released").as_u64(), Some(1));
        assert!(usage.table.contains("tenant"));
    }

    #[test]
    fn reservation_rpcs_withhold_capacity() {
        let (_s, mut c, _hv) = setup();
        let holder = c.add_user("holder").unwrap().user;
        let other = c.add_user("other").unwrap().user;
        // Reserve the whole 16-region testbed for the holder.
        let r = c
            .reserve(&ReserveRequest {
                user: holder,
                regions: 16,
                model: None,
                start_s: None,
                duration_s: Some(10_000.0),
            })
            .unwrap();
        let err = c.alloc_vfpga(other, None, None).unwrap_err();
        assert_eq!(err.code, ErrorCode::NoCapacity);
        c.cancel_reservation(r.reservation).unwrap();
        assert!(c.alloc_vfpga(other, None, None).is_ok());
    }

    #[test]
    fn monitor_exposes_sched_telemetry() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("m").unwrap().user;
        c.alloc_vfpga(user, None, None).unwrap();
        let mon = c.monitor().unwrap();
        let sched = &mon.sched;
        assert_eq!(sched.active_grants, 1);
        assert_eq!(sched.queue_depth, 0);
        // The grant above recorded one admission wait sample.
        assert!(sched.wait.count >= 1);
        // Lifecycle telemetry: the allocated-but-unprogrammed region
        // reads Reserved; nothing drains or migrates at rest; the
        // defense-in-depth raced counter is 0.
        assert_eq!(sched.lifecycle.reserved, 1);
        assert_eq!(sched.lifecycle.draining, 0);
        assert_eq!(sched.lifecycle.migrating, 0);
        assert_eq!(sched.preempt_raced, 0);
        // The same states are visible per device in `status`.
        let st = c.status(FpgaId(0)).unwrap();
        assert_eq!(st.regions_draining, 0);
        assert_eq!(st.regions_migrating, 0);
    }

    #[test]
    fn lifecycle_log_rpc_returns_transitions() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("log").unwrap().user;
        let lease = c.alloc_vfpga(user, None, None).unwrap();
        c.program_core(user, lease.alloc, "matmul16").unwrap();
        let log = c.lifecycle_log(lease.fpga, None).unwrap();
        assert_eq!(log.fpga, lease.fpga);
        assert_eq!(log.dropped, 0);
        // Free → Reserved → Programming → Active, in order.
        let edges: Vec<(String, String)> = log
            .records
            .iter()
            .map(|r| (r.from.clone(), r.to.clone()))
            .collect();
        assert_eq!(edges[0], ("free".to_string(), "reserved".to_string()));
        assert!(edges.contains(&(
            "programming".to_string(),
            "active".to_string()
        )));
        // A limit trims from the oldest end.
        let tail = c.lifecycle_log(lease.fpga, Some(1)).unwrap();
        assert_eq!(tail.records.len(), 1);
        assert_eq!(
            tail.records[0].to,
            log.records.last().unwrap().to
        );
        // Unknown device is a typed error.
        let err = c.lifecycle_log(FpgaId(99), None).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownDevice);
    }

    #[test]
    fn sched_policy_rpcs_roundtrip() {
        let (s, mut c, _hv) = setup();
        assert_eq!(c.sched_policy_get().unwrap().policy, "pack");
        let set = c.sched_policy_set("spread").unwrap();
        assert_eq!(set.policy, "spread");
        assert_eq!(
            s.scheduler().preempt_policy(),
            PreemptPolicy::Spread
        );
        assert_eq!(c.sched_policy_get().unwrap().policy, "spread");
        let err = c.sched_policy_set("randomly").unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn mutating_rpcs_require_the_lease_token() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("auth").unwrap().user;
        let lease = c.alloc_vfpga(user, None, None).unwrap();
        // A second client without the cached token is refused.
        let mut intruder = Client::connect(_s.addr()).unwrap();
        let err = intruder
            .program_core(user, lease.alloc, "matmul16")
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadToken);
        // A forged token is refused too.
        intruder.set_lease_token(lease.alloc, LeaseToken::mint());
        let err = intruder.release(lease.alloc).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadToken);
        // The rightful holder proceeds.
        assert!(c.release(lease.alloc).unwrap().released);
    }

    #[test]
    fn subscription_sees_sched_events() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("w").unwrap().user;
        let mut watcher = Client::connect(_s.addr()).unwrap();
        let stream_client = std::thread::spawn({
            let addr = _s.addr();
            move || {
                let mut c2 = Client::connect(addr).unwrap();
                // Give the watcher time to register.
                std::thread::sleep(Duration::from_millis(150));
                let lease = c2.alloc_vfpga(user, None, None).unwrap();
                c2.release(lease.alloc).unwrap();
            }
        });
        let frames: Vec<Event> = watcher
            .subscribe(&SubscribeRequest {
                filter: SubscriptionFilter::topic(Topic::Sched),
                lease: None,
                max_events: Some(1),
                timeout_s: Some(30.0),
                from_cursor: None,
            })
            .unwrap()
            .map(|r| r.unwrap().event)
            .collect();
        stream_client.join().unwrap();
        assert_eq!(frames.len(), 1);
        match &frames[0] {
            Event::GrantIssued { tenant, .. } => {
                assert_eq!(*tenant, user)
            }
            other => panic!("expected a grant event, got {other:?}"),
        }
        // The connection returned to request/response mode.
        assert!(watcher.hello().is_ok());
    }

    #[test]
    fn job_progress_frames_arrive_mid_job() {
        let (s, mut c, _hv) = setup();
        let user = c.add_user("p").unwrap().user;
        let lease = c.alloc_vfpga(user, None, None).unwrap();
        let token = c.lease_token(lease.alloc).unwrap();
        // Subscribe with the lease token (job events are
        // token-scoped), then submit the stream job.
        let mut watcher = Client::connect(s.addr()).unwrap();
        watcher.set_lease_token(lease.alloc, token);
        c.program_core(user, lease.alloc, "matmul16").unwrap();
        let submitted = std::sync::mpsc::channel();
        let submitter = std::thread::spawn({
            let addr = s.addr();
            let tx = submitted.0.clone();
            move || {
                let mut c2 = Client::connect(addr).unwrap();
                c2.set_lease_token(lease.alloc, token);
                std::thread::sleep(Duration::from_millis(150));
                let job = c2
                    .stream(user, lease.alloc, "matmul16", 64)
                    .unwrap()
                    .job;
                tx.send(job).unwrap();
                c2.set_job_token(job, token);
                let _ = c2.job_wait(job, Some(60.0));
            }
        });
        let frames: Vec<Event> = watcher
            .subscribe(&SubscribeRequest {
                filter: SubscriptionFilter::topic(Topic::Job),
                lease: Some(token),
                max_events: Some(2),
                timeout_s: Some(60.0),
                from_cursor: None,
            })
            .unwrap()
            .map(|r| r.unwrap().event)
            .collect();
        let job = submitted.1.recv().unwrap();
        submitter.join().unwrap();
        // The first frames are mid-job: running state, pct < 100.
        assert_eq!(frames.len(), 2);
        for f in &frames {
            match f {
                Event::JobProgress {
                    job: j,
                    state,
                    pct,
                    result,
                    ..
                } => {
                    assert_eq!(*j, job);
                    assert_eq!(state, "running");
                    assert!(*pct < 100.0);
                    assert!(result.is_none());
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
    }

    #[test]
    fn job_rpcs_still_owner_gated() {
        let (_s, mut c, _hv) = setup();
        let user = c.add_user("jobs").unwrap().user;
        let job = c.invoke_service(user, "no-such", 16).unwrap();
        // The submitter (token cached) can wait out the failure.
        let err = c.job_wait_done(job.job).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownService);
        // A stranger without the owner token cannot read the job.
        let mut stranger = Client::connect(_s.addr()).unwrap();
        let err = stranger.job_status(job.job).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadToken);
        // Unknown jobs read as unknown for everyone.
        let err = c.job_status(JobId(4242)).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownJob);
    }
}
