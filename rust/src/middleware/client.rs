//! Middleware client library (used by the CLI, by tests, and by the
//! management server when it talks to node agents).
//!
//! Two layers:
//!
//! * [`Client::call_v2`] — the raw escape hatch: string method + raw
//!   [`Json`] params over the current typed envelope (used by the
//!   `rc3e cli` passthrough). Protocol 1 — the old untyped envelope —
//!   is retired; every request is stamped with `proto`/`id`.
//! * Typed methods (`hello`, `alloc_vfpga`, `stream`, ...) — one per
//!   [`Method`]: typed request/response structs and structured
//!   [`ApiError`]s clients can branch on
//!   (`e.code == ErrorCode::QuotaExceeded`, `e.retry_after_s`).
//!
//! Long-running operations (`stream`, `program_full`,
//! `invoke_service`) return [`JobSubmitResponse`] handles; the
//! `*_sync` variants submit and [`Client::job_wait`] in one call,
//! reproducing the old blocking behavior.
//!
//! Protocol 3: [`Client::subscribe`] opens a server-push event
//! stream and returns an iterator-style [`EventStream`] handle over
//! typed [`Event`] frames (`rc3e watch` / `rc3e job --follow` are
//! thin wrappers around it). The handle drains the stream on drop so
//! the connection returns to request/response mode cleanly.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use super::api::*;
use super::proto::{
    read_frame, read_wire_frame, write_frame, Request, Response,
    StreamFrame, WireFrame,
};
use crate::config::ServiceModel;
use crate::sched::RequestClass;
use crate::util::ids::{
    AllocationId, FpgaId, JobId, LeaseToken, TraceId, UserId,
};
use crate::util::json::Json;

/// A connected middleware client.
///
/// The client keeps the capability tokens returned by the
/// `alloc_*` RPCs and attaches them automatically to every mutating
/// call on the same allocation (`program*`, `stream`, `release`,
/// `migrate`) and to `job_*` calls on jobs it submitted — callers
/// work with allocation/job ids while the wire carries the token.
/// [`Client::set_lease_token`] / [`Client::set_job_token`] inject
/// tokens obtained elsewhere (other connections, the CLI `--lease`
/// flag, or deliberately wrong ones in tests).
pub struct Client {
    stream: TcpStream,
    /// Protocol stamped on outgoing requests. Defaults to
    /// [`PROTO_MAX`]; [`Client::set_proto`] pins an older version
    /// (e.g. 3 to force the JSON data-frame fallback).
    proto: u32,
    /// Correlation-id counter for requests.
    next_id: u64,
    /// alloc → capability token, learned from alloc responses.
    lease_tokens: BTreeMap<AllocationId, LeaseToken>,
    /// job → owner token, learned from submit responses.
    job_tokens: BTreeMap<JobId, LeaseToken>,
    /// Trace id stamped on every outgoing request, so a multi-RPC
    /// workflow (alloc → program → stream) records as one connected
    /// trace in the server's flight recorder.
    trace_context: Option<TraceId>,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client, String> {
        let stream = TcpStream::connect_timeout(
            &addr,
            Duration::from_secs(5),
        )
        .map_err(|e| format!("connect {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .map_err(|e| e.to_string())?;
        Ok(Client {
            stream,
            proto: PROTO_MAX,
            next_id: 0,
            lease_tokens: BTreeMap::new(),
            job_tokens: BTreeMap::new(),
            trace_context: None,
        })
    }

    /// Pin the protocol stamped on outgoing requests (within the
    /// supported window). A client pinned to 3 never receives binary
    /// frames: the server falls back to base64 `stream_data` events.
    pub fn set_proto(&mut self, proto: u32) {
        self.proto = proto.clamp(PROTO_MIN, PROTO_MAX);
    }

    /// The protocol this client stamps on requests.
    pub fn proto(&self) -> u32 {
        self.proto
    }

    /// Mint a fresh trace id and stamp it on every request from here
    /// on; returns the id so the caller can `trace_get` it later.
    pub fn start_trace(&mut self) -> TraceId {
        let trace = TraceId::mint();
        self.trace_context = Some(trace);
        trace
    }

    /// Set (or clear, with `None`) the trace id stamped on requests.
    pub fn set_trace_context(&mut self, trace: Option<TraceId>) {
        self.trace_context = trace;
    }

    /// The trace id currently stamped on outgoing requests.
    pub fn trace_context(&self) -> Option<TraceId> {
        self.trace_context
    }

    /// The cached capability token for an allocation, if any.
    pub fn lease_token(&self, alloc: AllocationId) -> Option<LeaseToken> {
        self.lease_tokens.get(&alloc).copied()
    }

    /// Inject (or override) the token used for an allocation — for
    /// tokens handed over out of band, or to present a wrong one.
    pub fn set_lease_token(
        &mut self,
        alloc: AllocationId,
        token: LeaseToken,
    ) {
        self.lease_tokens.insert(alloc, token);
    }

    /// Inject (or override) the owner token used for a job.
    pub fn set_job_token(&mut self, job: JobId, token: LeaseToken) {
        self.job_tokens.insert(job, token);
    }

    /// Connect and negotiate the protocol via `hello`. Fails with
    /// [`ErrorCode::ProtocolMismatch`] when the windows don't
    /// overlap.
    pub fn connect_negotiated(
        addr: SocketAddr,
    ) -> Result<(Client, HelloResponse), ApiError> {
        let mut client =
            Client::connect(addr).map_err(ApiError::internal)?;
        let hello = client.hello()?;
        Ok((client, hello))
    }

    /// One request/response round trip: send the envelope, read the
    /// (header) response, verify the correlation id. Shared by
    /// [`Client::call_v2`] and [`Client::subscribe`].
    fn round_trip(
        &mut self,
        method: &str,
        params: Json,
    ) -> Result<Response, ApiError> {
        self.next_id += 1;
        let id = self.next_id;
        let mut req = Request::v2(method, params, id)
            .with_trace(self.trace_context);
        req.proto = Some(self.proto);
        write_frame(&mut self.stream, &req.to_json())
            .map_err(|e| ApiError::internal(format!("io: {e}")))?;
        let frame = read_frame(&mut self.stream)
            .map_err(|e| ApiError::internal(format!("io: {e}")))?
            .ok_or_else(|| {
                ApiError::internal("io: eof (server closed connection)")
            })?;
        let resp =
            Response::from_json(&frame).map_err(ApiError::internal)?;
        if resp.id != Some(id) {
            return Err(ApiError::internal(format!(
                "response id mismatch: sent {id}, got {:?}",
                resp.id
            )));
        }
        Ok(resp)
    }

    /// One raw round trip over the current envelope: correlation id
    /// attached and verified, structured errors surfaced as
    /// [`ApiError`]. This is the untyped escape hatch (`rc3e cli`).
    pub fn call_v2(
        &mut self,
        method: &str,
        params: Json,
    ) -> Result<Json, ApiError> {
        self.round_trip(method, params)?.into_api_result()
    }

    // --------------------------------------------- typed: handshake

    /// Version-negotiating handshake.
    pub fn hello(&mut self) -> Result<HelloResponse, ApiError> {
        let body = self.call_v2(
            Method::Hello.name(),
            HelloRequest::ours().to_json(),
        )?;
        HelloResponse::from_json(&body)
    }

    // ------------------------------------------------ typed: users

    pub fn add_user(
        &mut self,
        name: &str,
    ) -> Result<AddUserResponse, ApiError> {
        let req = AddUserRequest {
            name: name.to_string(),
        };
        let body =
            self.call_v2(Method::AddUser.name(), req.to_json())?;
        AddUserResponse::from_json(&body)
    }

    // ----------------------------------------------- typed: status

    pub fn status(
        &mut self,
        fpga: FpgaId,
    ) -> Result<StatusResponse, ApiError> {
        let req = StatusRequest { fpga };
        let body = self.call_v2(Method::Status.name(), req.to_json())?;
        StatusResponse::from_json(&body)
    }

    pub fn monitor(&mut self) -> Result<MonitorResponse, ApiError> {
        let body = self.call_v2(
            Method::Monitor.name(),
            MonitorRequest.to_json(),
        )?;
        MonitorResponse::from_json(&body)
    }

    pub fn energy(&mut self) -> Result<EnergyResponse, ApiError> {
        let body = self
            .call_v2(Method::Energy.name(), EnergyRequest.to_json())?;
        EnergyResponse::from_json(&body)
    }

    pub fn db_dump(&mut self) -> Result<DbDumpResponse, ApiError> {
        let body = self
            .call_v2(Method::DbDump.name(), DbDumpRequest.to_json())?;
        DbDumpResponse::from_json(&body)
    }

    pub fn workload(
        &mut self,
        req: &WorkloadRequest,
    ) -> Result<WorkloadResponse, ApiError> {
        let body =
            self.call_v2(Method::Workload.name(), req.to_json())?;
        WorkloadResponse::from_json(&body)
    }

    /// The newest records of one device's region lifecycle
    /// transition log.
    pub fn lifecycle_log(
        &mut self,
        fpga: FpgaId,
        limit: Option<u64>,
    ) -> Result<LifecycleLogResponse, ApiError> {
        let req = LifecycleLogRequest { fpga, limit };
        let body =
            self.call_v2(Method::LifecycleLog.name(), req.to_json())?;
        LifecycleLogResponse::from_json(&body)
    }

    // ------------------------------------------------ typed: leases

    /// Allocate vFPGAs: one by default, an atomic gang when the
    /// request's `regions > 1`. The returned capability token is
    /// cached for every member allocation.
    pub fn alloc_vfpga_with(
        &mut self,
        req: &AllocVfpgaRequest,
    ) -> Result<AllocVfpgaResponse, ApiError> {
        let body =
            self.call_v2(Method::AllocVfpga.name(), req.to_json())?;
        let resp = AllocVfpgaResponse::from_json(&body)?;
        for m in &resp.members {
            self.lease_tokens.insert(m.alloc, resp.lease);
        }
        Ok(resp)
    }

    /// Single-region allocation (the common case).
    pub fn alloc_vfpga(
        &mut self,
        user: UserId,
        model: Option<ServiceModel>,
        class: Option<RequestClass>,
    ) -> Result<AllocVfpgaResponse, ApiError> {
        self.alloc_vfpga_with(&AllocVfpgaRequest::single(
            user, model, class,
        ))
    }

    pub fn alloc_physical(
        &mut self,
        user: UserId,
    ) -> Result<AllocPhysicalResponse, ApiError> {
        let req = AllocPhysicalRequest { user };
        let body =
            self.call_v2(Method::AllocPhysical.name(), req.to_json())?;
        let resp = AllocPhysicalResponse::from_json(&body)?;
        self.lease_tokens.insert(resp.alloc, resp.lease);
        Ok(resp)
    }

    pub fn release(
        &mut self,
        alloc: AllocationId,
    ) -> Result<ReleaseResponse, ApiError> {
        let req = ReleaseRequest {
            alloc,
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::Release.name(), req.to_json())?;
        let resp = ReleaseResponse::from_json(&body)?;
        // The whole lease is gone server-side; drop every cached
        // member token for it.
        if let Some(token) = self.lease_tokens.remove(&alloc) {
            self.lease_tokens.retain(|_, t| *t != token);
        }
        Ok(resp)
    }

    pub fn program_core(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        core: &str,
    ) -> Result<ProgramCoreResponse, ApiError> {
        let req = ProgramCoreRequest {
            user,
            alloc,
            core: core.to_string(),
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::ProgramCore.name(), req.to_json())?;
        ProgramCoreResponse::from_json(&body)
    }

    pub fn migrate(
        &mut self,
        user: UserId,
        alloc: AllocationId,
    ) -> Result<MigrateResponse, ApiError> {
        let req = MigrateRequest {
            user,
            alloc,
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::Migrate.name(), req.to_json())?;
        MigrateResponse::from_json(&body)
    }

    // ------------------------------------------- typed: catalogues

    pub fn services(&mut self) -> Result<ServicesResponse, ApiError> {
        let body = self.call_v2(
            Method::Services.name(),
            ServicesRequest.to_json(),
        )?;
        ServicesResponse::from_json(&body)
    }

    pub fn cores(&mut self) -> Result<CoresResponse, ApiError> {
        let body =
            self.call_v2(Method::Cores.name(), CoresRequest.to_json())?;
        CoresResponse::from_json(&body)
    }

    // ------------------------------- typed: long-running operations

    /// Submit a streaming run; returns a job handle immediately.
    pub fn stream(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        core: &str,
        mults: u64,
    ) -> Result<JobSubmitResponse, ApiError> {
        let req = StreamRequest {
            user,
            alloc,
            core: core.to_string(),
            mults,
            lease: self.lease_token(alloc),
            emit_output: false,
        };
        let body =
            self.call_v2(Method::Stream.name(), req.to_json())?;
        let resp = JobSubmitResponse::from_json(&body)?;
        if let Some(t) = resp.lease {
            self.job_tokens.insert(resp.job, t);
        }
        Ok(resp)
    }

    /// Submit + wait: the old synchronous `stream` behavior.
    pub fn stream_sync(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        core: &str,
        mults: u64,
    ) -> Result<StreamOutcomeBody, ApiError> {
        let job = self.stream(user, alloc, core, mults)?.job;
        let result = self.job_wait_done(job)?;
        StreamOutcomeBody::from_json(&result)
    }

    /// Stream with the output payload delivered over the data plane:
    /// the server replies with a stream header, then data frames —
    /// out-of-band binary frames when this client speaks protocol 4,
    /// base64 `stream_data` events on protocol 3 — then a JSON
    /// terminal frame whose `stats` carry the [`StreamOutcomeBody`].
    /// Output bytes are appended to `out`. Synchronous on the
    /// connection: no job handle, the connection is dedicated to the
    /// stream until the terminal frame.
    pub fn stream_data(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        core: &str,
        mults: u64,
        out: &mut Vec<u8>,
    ) -> Result<StreamOutcomeBody, ApiError> {
        let req = StreamRequest {
            user,
            alloc,
            core: core.to_string(),
            mults,
            lease: self.lease_token(alloc),
            emit_output: true,
        };
        self.next_id += 1;
        let id = self.next_id;
        let mut env =
            Request::v2(Method::Stream.name(), req.to_json(), id)
                .with_trace(self.trace_context);
        env.proto = Some(self.proto);
        write_frame(&mut self.stream, &env.to_json())
            .map_err(|e| ApiError::internal(format!("io: {e}")))?;
        let header = read_frame(&mut self.stream)
            .map_err(|e| ApiError::internal(format!("io: {e}")))?
            .ok_or_else(|| {
                ApiError::internal("io: eof (server closed connection)")
            })?;
        let resp =
            Response::from_json(&header).map_err(ApiError::internal)?;
        let is_stream = resp.stream;
        resp.into_api_result()?;
        if !is_stream {
            return Err(ApiError::internal(
                "stream response was not a data-plane header",
            ));
        }
        // Data frames until the JSON terminal. Sequence numbers are
        // shared across both framings and strictly increasing.
        let mut last_seq = 0u64;
        loop {
            let frame = read_wire_frame(&mut self.stream)
                .map_err(|e| ApiError::internal(format!("io: {e}")))?
                .ok_or_else(|| {
                    ApiError::internal("io: eof mid-stream")
                })?;
            match frame {
                WireFrame::Bin(b) => {
                    if b.seq <= last_seq {
                        return Err(ApiError::internal(
                            "data frame sequence went backwards",
                        ));
                    }
                    last_seq = b.seq;
                    out.extend_from_slice(&b.payload);
                }
                WireFrame::Json(v) => {
                    let f = StreamFrame::from_json(&v)
                        .map_err(ApiError::internal)?;
                    if f.end {
                        if let Some(e) = f.error {
                            return Err(e);
                        }
                        let stats = f.stats.ok_or_else(|| {
                            ApiError::internal(
                                "terminal frame missing outcome stats",
                            )
                        })?;
                        return StreamOutcomeBody::from_json(&stats);
                    }
                    if f.seq <= last_seq {
                        return Err(ApiError::internal(
                            "data frame sequence went backwards",
                        ));
                    }
                    last_seq = f.seq;
                    if let Some(ev) = &f.event {
                        if let Some(b64) = ev.get("b64").as_str() {
                            let bytes =
                                crate::util::bytes::b64_decode(b64)
                                    .map_err(|e| {
                                        ApiError::internal(format!(
                                            "bad stream_data frame: {e}"
                                        ))
                                    })?;
                            out.extend_from_slice(&bytes);
                        }
                    }
                }
            }
        }
    }

    /// Submit a full-bitstream configuration; returns a job handle.
    pub fn program_full(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        name: Option<&str>,
    ) -> Result<JobSubmitResponse, ApiError> {
        let req = ProgramFullRequest {
            user,
            alloc,
            name: name.map(String::from),
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::ProgramFull.name(), req.to_json())?;
        let resp = JobSubmitResponse::from_json(&body)?;
        if let Some(t) = resp.lease {
            self.job_tokens.insert(resp.job, t);
        }
        Ok(resp)
    }

    /// Submit + wait: the old synchronous `program_full` behavior.
    pub fn program_full_sync(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        name: Option<&str>,
    ) -> Result<ProgramFullResponse, ApiError> {
        let job = self.program_full(user, alloc, name)?.job;
        let result = self.job_wait_done(job)?;
        ProgramFullResponse::from_json(&result)
    }

    /// Submit a BAaaS service invocation; returns a job handle.
    pub fn invoke_service(
        &mut self,
        user: UserId,
        service: &str,
        mults: u64,
    ) -> Result<JobSubmitResponse, ApiError> {
        let req = InvokeServiceRequest {
            user,
            service: service.to_string(),
            mults,
        };
        let body =
            self.call_v2(Method::InvokeService.name(), req.to_json())?;
        let resp = JobSubmitResponse::from_json(&body)?;
        if let Some(t) = resp.lease {
            self.job_tokens.insert(resp.job, t);
        }
        Ok(resp)
    }

    /// Submit + wait: the old synchronous `invoke_service` behavior.
    pub fn invoke_service_sync(
        &mut self,
        user: UserId,
        service: &str,
        mults: u64,
    ) -> Result<StreamOutcomeBody, ApiError> {
        let job = self.invoke_service(user, service, mults)?.job;
        let result = self.job_wait_done(job)?;
        StreamOutcomeBody::from_json(&result)
    }

    // -------------------------------------------------- typed: jobs

    pub fn job_status(
        &mut self,
        job: JobId,
    ) -> Result<JobBody, ApiError> {
        let req = JobStatusRequest {
            job,
            lease: self.job_tokens.get(&job).copied(),
        };
        let body =
            self.call_v2(Method::JobStatus.name(), req.to_json())?;
        JobBody::from_json(&body)
    }

    /// Wait until the job is terminal (one server-side wait round;
    /// pass `timeout_s` to bound it, server default otherwise).
    pub fn job_wait(
        &mut self,
        job: JobId,
        timeout_s: Option<f64>,
    ) -> Result<JobBody, ApiError> {
        let req = JobWaitRequest {
            job,
            timeout_s,
            lease: self.job_tokens.get(&job).copied(),
        };
        let body =
            self.call_v2(Method::JobWait.name(), req.to_json())?;
        JobBody::from_json(&body)
    }

    /// Wait for a job and unwrap its `done` result, retrying through
    /// server-side wait timeouts (which are retryable by contract).
    pub fn job_wait_done(
        &mut self,
        job: JobId,
    ) -> Result<Json, ApiError> {
        loop {
            match self.job_wait(job, None) {
                Ok(body) => return body.into_done(),
                Err(e) if e.code == ErrorCode::Timeout => continue,
                Err(e) => return Err(e),
            }
        }
    }

    pub fn job_cancel(
        &mut self,
        job: JobId,
    ) -> Result<JobBody, ApiError> {
        let req = JobCancelRequest {
            job,
            lease: self.job_tokens.get(&job).copied(),
        };
        let body =
            self.call_v2(Method::JobCancel.name(), req.to_json())?;
        JobBody::from_json(&body)
    }

    // --------------------------------------- typed: event streaming

    /// Open a server-push subscription (protocol 3). Returns an
    /// iterator over typed event frames; the stream ends at the
    /// server's terminal frame (timeout or `max_events` reached).
    /// While the [`EventStream`] lives, the connection is dedicated
    /// to it — drop (or exhaust) the stream before issuing other
    /// calls; dropping drains any remaining frames so the connection
    /// stays usable. **Dropping mid-stream blocks until the server's
    /// terminal frame**, i.e. up to the subscription's (clamped)
    /// `timeout_s` on a quiet topic — abandon-early callers should
    /// bound the stream with `max_events` or short `timeout_s`
    /// rounds instead of breaking out of an unbounded one.
    pub fn subscribe(
        &mut self,
        req: &SubscribeRequest,
    ) -> Result<EventStream<'_>, ApiError> {
        let resp =
            self.round_trip(Method::Subscribe.name(), req.to_json())?;
        let is_stream = resp.stream;
        let body = resp.into_api_result()?;
        if !is_stream {
            return Err(ApiError::internal(
                "subscribe response was not a stream header",
            ));
        }
        let header = SubscribeResponse::from_json(&body)?;
        Ok(EventStream {
            client: self,
            header,
            last_seq: 0,
            done: false,
            stats: None,
        })
    }

    // --------------------------------------------- typed: scheduler

    /// Scheduler queue/grant/reservation snapshot.
    pub fn sched_status(
        &mut self,
    ) -> Result<SchedStatusResponse, ApiError> {
        let body = self.call_v2(
            Method::SchedStatus.name(),
            SchedStatusRequest.to_json(),
        )?;
        SchedStatusResponse::from_json(&body)
    }

    /// Where preemption relocates its victims.
    pub fn sched_policy_get(
        &mut self,
    ) -> Result<SchedPolicyResponse, ApiError> {
        let body = self.call_v2(
            Method::SchedPolicyGet.name(),
            SchedPolicyGetRequest.to_json(),
        )?;
        SchedPolicyResponse::from_json(&body)
    }

    /// Set the preemption landing policy ("spread" | "pack").
    pub fn sched_policy_set(
        &mut self,
        policy: &str,
    ) -> Result<SchedPolicyResponse, ApiError> {
        let req = SchedPolicySetRequest {
            policy: policy.to_string(),
        };
        let body = self
            .call_v2(Method::SchedPolicySet.name(), req.to_json())?;
        SchedPolicyResponse::from_json(&body)
    }

    /// Set (parts of) a tenant quota; unspecified fields keep their
    /// current values server-side. `max_vfpgas: 0` restores an
    /// unlimited cap; a negative `budget_s` clears the budget.
    pub fn quota_set(
        &mut self,
        req: &QuotaSetRequest,
    ) -> Result<QuotaResponse, ApiError> {
        let body =
            self.call_v2(Method::QuotaSet.name(), req.to_json())?;
        QuotaResponse::from_json(&body)
    }

    pub fn quota_get(
        &mut self,
        user: UserId,
    ) -> Result<QuotaResponse, ApiError> {
        let req = QuotaGetRequest { user };
        let body =
            self.call_v2(Method::QuotaGet.name(), req.to_json())?;
        QuotaResponse::from_json(&body)
    }

    /// Per-tenant usage rows + rendered operator table.
    pub fn usage_report(
        &mut self,
    ) -> Result<UsageReportResponse, ApiError> {
        let body = self.call_v2(
            Method::UsageReport.name(),
            UsageReportRequest.to_json(),
        )?;
        UsageReportResponse::from_json(&body)
    }

    /// Reserve vFPGA capacity for a tenant over a virtual-time window.
    pub fn reserve(
        &mut self,
        req: &ReserveRequest,
    ) -> Result<ReserveResponse, ApiError> {
        let body =
            self.call_v2(Method::Reserve.name(), req.to_json())?;
        ReserveResponse::from_json(&body)
    }

    pub fn cancel_reservation(
        &mut self,
        reservation: crate::util::ids::ReservationId,
    ) -> Result<CancelReservationResponse, ApiError> {
        let req = CancelReservationRequest { reservation };
        let body = self
            .call_v2(Method::CancelReservation.name(), req.to_json())?;
        CancelReservationResponse::from_json(&body)
    }

    // --------------------------------------- typed: observability

    /// Dump every registered instrument (counters, gauges, histograms
    /// with bucket boundaries).
    pub fn metrics_export(
        &mut self,
    ) -> Result<MetricsExportResponse, ApiError> {
        let body = self.call_v2(
            Method::MetricsExport.name(),
            MetricsExportRequest.to_json(),
        )?;
        MetricsExportResponse::from_json(&body)
    }

    /// Fetch a span tree from the server's flight recorder.
    pub fn trace_get(
        &mut self,
        req: &TraceGetRequest,
    ) -> Result<TraceGetResponse, ApiError> {
        let body =
            self.call_v2(Method::TraceGet.name(), req.to_json())?;
        TraceGetResponse::from_json(&body)
    }

    // --------------------------------------- typed: bitstream cache

    /// Submit an ahead-of-time compile for a core. Returns a digest
    /// ticket: `cached` immediately, or `submitted` / `coalesced`
    /// with the flow job to `job_wait` on.
    pub fn compile_submit(
        &mut self,
        req: &CompileSubmitRequest,
    ) -> Result<CompileSubmitResponse, ApiError> {
        let body =
            self.call_v2(Method::CompileSubmit.name(), req.to_json())?;
        let resp = CompileSubmitResponse::from_json(&body)?;
        if let (Some(job), Some(t)) = (resp.job, resp.lease) {
            self.job_tokens.insert(job, t);
        }
        Ok(resp)
    }

    /// Poll a compile digest: `cached`, `running`, or `unknown`.
    pub fn compile_status(
        &mut self,
        digest: &str,
    ) -> Result<CompileStatusResponse, ApiError> {
        let req = CompileStatusRequest {
            digest: digest.to_string(),
        };
        let body =
            self.call_v2(Method::CompileStatus.name(), req.to_json())?;
        CompileStatusResponse::from_json(&body)
    }

    /// Pull a bitstream artifact from the management cache — the node
    /// daemon's warm-up path (`agent.fetch_bitstream`). The reply is
    /// a stream: a JSON header with the lossless transfer metadata
    /// (payload out-of-band), then the payload as data frames —
    /// binary when this client speaks protocol 4, base64
    /// `stream_data` events on protocol 3 — then a terminal frame
    /// whose stats carry the byte count and sha256. The reassembled
    /// bitstream is CRC-verified before it is returned. `node` is the
    /// caller's self-identification when it is a node daemon — the
    /// management side marks that node warm for the core.
    pub fn fetch_bitstream(
        &mut self,
        core: &str,
        part: &str,
        node: Option<crate::util::ids::NodeId>,
    ) -> Result<crate::bitstream::Bitstream, ApiError> {
        let req = FetchBitstreamRequest {
            core: core.to_string(),
            part: part.to_string(),
            node,
        };
        let resp = self.round_trip(
            Method::AgentFetchBitstream.name(),
            req.to_json(),
        )?;
        let is_stream = resp.stream;
        let meta = resp.into_api_result()?;
        if !is_stream {
            return Err(ApiError::internal(
                "fetch_bitstream response was not a stream header",
            ));
        }
        let mut payload = Vec::new();
        let mut last_seq = 0u64;
        loop {
            let frame = read_wire_frame(&mut self.stream)
                .map_err(|e| ApiError::internal(format!("io: {e}")))?
                .ok_or_else(|| {
                    ApiError::internal("io: eof mid-transfer")
                })?;
            match frame {
                WireFrame::Bin(b) => {
                    if b.seq <= last_seq {
                        return Err(ApiError::internal(
                            "transfer frame sequence went backwards",
                        ));
                    }
                    last_seq = b.seq;
                    payload.extend_from_slice(&b.payload);
                }
                WireFrame::Json(v) => {
                    let f = StreamFrame::from_json(&v)
                        .map_err(ApiError::internal)?;
                    if f.end {
                        if let Some(e) = f.error {
                            return Err(e);
                        }
                        break;
                    }
                    if f.seq <= last_seq {
                        return Err(ApiError::internal(
                            "transfer frame sequence went backwards",
                        ));
                    }
                    last_seq = f.seq;
                    if let Some(ev) = &f.event {
                        if let Some(b64) = ev.get("b64").as_str() {
                            let bytes =
                                crate::util::bytes::b64_decode(b64)
                                    .map_err(|e| {
                                        ApiError::internal(format!(
                                            "bad transfer frame: {e}"
                                        ))
                                    })?;
                            payload.extend_from_slice(&bytes);
                        }
                    }
                }
            }
        }
        let bs = crate::bitstream::Bitstream::from_transfer_json(
            &meta,
            Some(payload),
        )
        .ok_or_else(|| {
            ApiError::internal("unparsable bitstream transfer header")
        })?;
        if !bs.crc_ok() {
            return Err(ApiError::internal(
                "bitstream transfer corrupted: CRC mismatch",
            ));
        }
        Ok(bs)
    }

    // ------------------------------------------------- typed: agent

    pub fn agent_hello(
        &mut self,
    ) -> Result<AgentHelloResponse, ApiError> {
        let body = self.call_v2(
            Method::AgentHello.name(),
            AgentHelloRequest.to_json(),
        )?;
        AgentHelloResponse::from_json(&body)
    }

    pub fn agent_status(
        &mut self,
        fpga: FpgaId,
    ) -> Result<StatusResponse, ApiError> {
        let req = StatusRequest { fpga };
        let body =
            self.call_v2(Method::AgentStatus.name(), req.to_json())?;
        StatusResponse::from_json(&body)
    }

    /// Heartbeat a node daemon: identity + live vitals.
    pub fn agent_ping(&mut self) -> Result<AgentPingResponse, ApiError> {
        let body = self.call_v2(
            Method::AgentPing.name(),
            AgentPingRequest.to_json(),
        )?;
        AgentPingResponse::from_json(&body)
    }

    /// Admit (or adopt) a lease on a node daemon.
    pub fn agent_admit(
        &mut self,
        req: &AgentAdmitRequest,
    ) -> Result<AllocVfpgaResponse, ApiError> {
        let body =
            self.call_v2(Method::AgentAdmit.name(), req.to_json())?;
        AllocVfpgaResponse::from_json(&body)
    }

    /// Release a lease on a node daemon by token.
    pub fn agent_release(
        &mut self,
        lease: LeaseToken,
    ) -> Result<ReleaseResponse, ApiError> {
        let req = AgentReleaseRequest { lease };
        let body =
            self.call_v2(Method::AgentRelease.name(), req.to_json())?;
        ReleaseResponse::from_json(&body)
    }

    /// Program a prebuilt core on a node daemon.
    pub fn agent_program(
        &mut self,
        req: &AgentProgramRequest,
    ) -> Result<ProgramCoreResponse, ApiError> {
        let body =
            self.call_v2(Method::AgentProgram.name(), req.to_json())?;
        ProgramCoreResponse::from_json(&body)
    }

    /// Stream a workload through a node daemon (synchronous on the
    /// agent wire; the management server wraps this in an async job).
    pub fn agent_stream(
        &mut self,
        req: &AgentStreamRequest,
    ) -> Result<StreamOutcomeBody, ApiError> {
        let body =
            self.call_v2(Method::AgentStream.name(), req.to_json())?;
        StreamOutcomeBody::from_json(&body)
    }

    /// Drain a node daemon's event journal from a cursor (long-poll).
    pub fn agent_events(
        &mut self,
        req: &AgentEventsRequest,
    ) -> Result<AgentEventsResponse, ApiError> {
        let body =
            self.call_v2(Method::AgentEvents.name(), req.to_json())?;
        AgentEventsResponse::from_json(&body)
    }

    // ----------------------------------------------- typed: cluster

    /// List the cluster's registered nodes (management server).
    pub fn node_list(&mut self) -> Result<NodeListResponse, ApiError> {
        let body = self.call_v2(
            Method::NodeList.name(),
            NodeListRequest.to_json(),
        )?;
        NodeListResponse::from_json(&body)
    }

    /// Register a node daemon with a federated management server.
    pub fn cluster_register(
        &mut self,
        req: &ClusterRegisterRequest,
    ) -> Result<ClusterRegisterResponse, ApiError> {
        let body = self
            .call_v2(Method::ClusterRegister.name(), req.to_json())?;
        ClusterRegisterResponse::from_json(&body)
    }
}

// ======================================================= event stream

/// One delivered subscription frame: the server-assigned sequence
/// number (strictly increasing per subscription) and the typed event.
#[derive(Debug, Clone, PartialEq)]
pub struct EventFrame {
    pub seq: u64,
    pub event: Event,
    /// Durable journal cursor of the event. Quote `cursor + 1` as
    /// `SubscribeRequest::from_cursor` to resume after this frame
    /// with no gaps and (after client-side dedup) no duplicates.
    /// `None` from servers without an event journal surface.
    pub cursor: Option<u64>,
}

/// Iterator-style handle over one `subscribe` stream. Yields frames
/// until the server's terminal frame; enforces strict `seq` ordering.
/// Dropping the handle mid-stream drains the remaining frames so the
/// underlying connection returns to request/response mode.
pub struct EventStream<'a> {
    client: &'a mut Client,
    header: SubscribeResponse,
    last_seq: u64,
    done: bool,
    /// Backpressure stats from the terminal frame (`delivered`,
    /// `dropped`, `queue_high_water`), once the stream ended.
    stats: Option<Json>,
}

impl EventStream<'_> {
    /// The stream header (subscription id + effective bounds).
    pub fn header(&self) -> &SubscribeResponse {
        &self.header
    }

    /// The terminal frame's per-subscriber delivery stats; `None`
    /// until the stream has ended (or on old servers).
    pub fn stats(&self) -> Option<&Json> {
        self.stats.as_ref()
    }

    fn read_one(&mut self) -> Result<Option<EventFrame>, ApiError> {
        let frame = read_frame(&mut self.client.stream)
            .map_err(|e| ApiError::internal(format!("io: {e}")))?
            .ok_or_else(|| {
                ApiError::internal("io: eof mid-subscription")
            })?;
        let sf = StreamFrame::from_json(&frame)
            .map_err(ApiError::internal)?;
        if sf.seq <= self.last_seq {
            return Err(ApiError::internal(format!(
                "stream frames out of order: {} after {}",
                sf.seq, self.last_seq
            )));
        }
        self.last_seq = sf.seq;
        if sf.end {
            self.done = true;
            self.stats = sf.stats;
            return match sf.error {
                Some(e) => Err(e),
                None => Ok(None),
            };
        }
        let event = sf.event.ok_or_else(|| {
            ApiError::internal("non-terminal frame without event")
        })?;
        Ok(Some(EventFrame {
            seq: sf.seq,
            event: Event::from_json(&event)?,
            cursor: sf.cursor,
        }))
    }
}

impl Iterator for EventStream<'_> {
    type Item = Result<EventFrame, ApiError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        match self.read_one() {
            Ok(Some(frame)) => Some(Ok(frame)),
            Ok(None) => None,
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

impl Drop for EventStream<'_> {
    fn drop(&mut self) {
        // Drain to the terminal frame so the connection is clean for
        // the next request. Bounded server-side by the subscription
        // timeout; an IO error just poisons this connection.
        while !self.done {
            if self.read_one().is_err() {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Minimal typed-envelope echo server for client-side tests.
    /// `fail` answers a structured error; `subscribe` answers a
    /// stream header + two event frames + terminal.
    fn echo_server() -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                std::thread::spawn(move || {
                    while let Ok(Some(frame)) = read_frame(&mut stream) {
                        let req = Request::from_json(&frame).unwrap();
                        if req.method == "subscribe" {
                            let header = Response::stream_header(
                                req.id,
                                SubscribeResponse {
                                    subscription: 1,
                                    timeout_s: 1.0,
                                }
                                .to_json(),
                            );
                            let frames = [
                                header.to_json(),
                                StreamFrame::event(
                                    1,
                                    Event::QueueDepth { depth: 1 }
                                        .to_json(),
                                )
                                .to_json(),
                                StreamFrame::event(
                                    2,
                                    Event::QueueDepth { depth: 0 }
                                        .to_json(),
                                )
                                .to_json(),
                                StreamFrame::terminal(3, None).to_json(),
                            ];
                            for f in frames {
                                if write_frame(&mut stream, &f).is_err()
                                {
                                    return;
                                }
                            }
                            continue;
                        }
                        let resp = if req.method == "fail" {
                            Response::failure(
                                req.id,
                                ApiError::new(
                                    ErrorCode::NoCapacity,
                                    "requested failure",
                                ),
                            )
                        } else {
                            Response::success_v2(req.id, req.params)
                        };
                        if write_frame(&mut stream, &resp.to_json()).is_err()
                        {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn call_v2_checks_id_and_carries_codes() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        let params = Json::obj(vec![("x", Json::from(7u64))]);
        let body = c.call_v2("echo", params.clone()).unwrap();
        assert_eq!(body, params);
        let err = c.call_v2("fail", Json::obj(vec![])).unwrap_err();
        assert_eq!(err.code, ErrorCode::NoCapacity);
        assert!(err.retryable);
        assert_eq!(err.message, "requested failure");
    }

    #[test]
    fn subscription_stream_iterates_frames_in_order() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        let frames: Vec<EventFrame> = c
            .subscribe(&SubscribeRequest {
                filter: SubscriptionFilter::all(),
                lease: None,
                max_events: None,
                timeout_s: None,
                from_cursor: None,
            })
            .unwrap()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].seq, 1);
        assert_eq!(frames[1].seq, 2);
        assert_eq!(
            frames[1].event,
            Event::QueueDepth { depth: 0 }
        );
        // The connection is usable for plain calls afterwards.
        let body = c.call_v2("echo", Json::obj(vec![])).unwrap();
        assert_eq!(body, Json::obj(vec![]));
    }

    #[test]
    fn dropping_a_stream_mid_read_drains_it() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        {
            let mut stream = c
                .subscribe(&SubscribeRequest {
                    filter: SubscriptionFilter::all(),
                    lease: None,
                    max_events: None,
                    timeout_s: None,
                    from_cursor: None,
                })
                .unwrap();
            // Read only the first of two frames, then drop.
            let first = stream.next().unwrap().unwrap();
            assert_eq!(first.seq, 1);
        }
        // The drain left the connection clean.
        let params = Json::obj(vec![("y", Json::from(1u64))]);
        assert_eq!(c.call_v2("echo", params.clone()).unwrap(), params);
    }

    #[test]
    fn connect_refused_is_error() {
        // Port 1 on loopback is almost certainly closed.
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        assert!(Client::connect(addr).is_err());
    }

    #[test]
    fn sequential_calls_reuse_connection() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        for i in 0..5u64 {
            let body = c
                .call_v2("echo", Json::obj(vec![("i", Json::from(i))]))
                .unwrap();
            assert_eq!(body.get("i").as_u64(), Some(i));
        }
    }
}
