//! Middleware client library (used by the CLI and by the management
//! server when it talks to node agents).

use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use super::proto::{read_frame, write_frame, Request, Response};
use crate::util::json::Json;

/// A connected middleware client.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client, String> {
        let stream = TcpStream::connect_timeout(
            &addr,
            Duration::from_secs(5),
        )
        .map_err(|e| format!("connect {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .map_err(|e| e.to_string())?;
        Ok(Client { stream })
    }

    /// One round trip. Errors are strings: either transport ("io: …")
    /// or application (the server's error body).
    pub fn call(&mut self, method: &str, params: Json) -> Result<Json, String> {
        let req = Request::new(method, params);
        write_frame(&mut self.stream, &req.to_json())
            .map_err(|e| format!("io: {e}"))?;
        let frame = read_frame(&mut self.stream)
            .map_err(|e| format!("io: {e}"))?
            .ok_or_else(|| "io: eof (server closed connection)".to_string())?;
        Response::from_json(&frame)?.into_result()
    }

    // ------------------------------------ sched-family conveniences

    /// Scheduler queue/grant/reservation snapshot.
    pub fn sched_status(&mut self) -> Result<Json, String> {
        self.call("sched_status", Json::obj(vec![]))
    }

    /// Set (parts of) a tenant quota; unspecified fields keep their
    /// current values server-side. `max_vfpgas: 0` restores an
    /// unlimited cap; a negative `budget_s` clears the budget.
    pub fn quota_set(
        &mut self,
        user: &str,
        max_vfpgas: Option<u64>,
        budget_s: Option<f64>,
        weight: Option<u64>,
    ) -> Result<Json, String> {
        let mut params = Json::obj(vec![("user", Json::from(user))]);
        if let Some(m) = max_vfpgas {
            params.set("max_vfpgas", Json::from(m));
        }
        if let Some(b) = budget_s {
            params.set("budget_s", Json::from(b));
        }
        if let Some(w) = weight {
            params.set("weight", Json::from(w));
        }
        self.call("quota_set", params)
    }

    pub fn quota_get(&mut self, user: &str) -> Result<Json, String> {
        self.call(
            "quota_get",
            Json::obj(vec![("user", Json::from(user))]),
        )
    }

    /// Per-tenant usage rows + rendered operator table.
    pub fn usage_report(&mut self) -> Result<Json, String> {
        self.call("usage_report", Json::obj(vec![]))
    }

    /// Reserve vFPGA capacity for a tenant over a virtual-time window.
    pub fn reserve(
        &mut self,
        user: &str,
        regions: u64,
        duration_s: f64,
    ) -> Result<Json, String> {
        self.call(
            "reserve",
            Json::obj(vec![
                ("user", Json::from(user)),
                ("regions", Json::from(regions)),
                ("duration_s", Json::from(duration_s)),
            ]),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Minimal echo server for client-side tests.
    fn echo_server() -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                std::thread::spawn(move || {
                    while let Ok(Some(frame)) = read_frame(&mut stream) {
                        let req = Request::from_json(&frame).unwrap();
                        let resp = if req.method == "fail" {
                            Response::error("requested failure")
                        } else {
                            Response::success(req.params)
                        };
                        if write_frame(&mut stream, &resp.to_json()).is_err()
                        {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn call_roundtrips_params() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        let params = Json::obj(vec![("x", Json::from(7u64))]);
        let body = c.call("echo", params.clone()).unwrap();
        assert_eq!(body, params);
    }

    #[test]
    fn application_errors_surface() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        assert_eq!(
            c.call("fail", Json::obj(vec![])),
            Err("requested failure".to_string())
        );
    }

    #[test]
    fn connect_refused_is_error() {
        // Port 1 on loopback is almost certainly closed.
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        assert!(Client::connect(addr).is_err());
    }

    #[test]
    fn sequential_calls_reuse_connection() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        for i in 0..5u64 {
            let body = c
                .call("echo", Json::obj(vec![("i", Json::from(i))]))
                .unwrap();
            assert_eq!(body.get("i").as_u64(), Some(i));
        }
    }
}
