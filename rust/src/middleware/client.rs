//! Middleware client library (used by the CLI, by tests, and by the
//! management server when it talks to node agents).
//!
//! Two layers:
//!
//! * [`Client::call`] — the raw protocol-1 escape hatch: string
//!   method + raw [`Json`] params, string errors. Kept for the `rc3e
//!   cli` passthrough and for legacy callers.
//! * Typed methods (`hello`, `alloc_vfpga`, `stream`, ...) — one per
//!   [`Method`], built on [`Client::call_v2`]: protocol-2 envelopes
//!   with correlation ids, typed request/response structs and
//!   structured [`ApiError`]s clients can branch on
//!   (`e.code == ErrorCode::QuotaExceeded`, `e.retry_after_s`).
//!
//! Long-running operations (`stream`, `program_full`,
//! `invoke_service`) return [`JobSubmitResponse`] handles; the
//! `*_sync` variants submit and [`Client::job_wait`] in one call,
//! reproducing the old blocking behavior.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use super::api::*;
use super::proto::{read_frame, write_frame, Request, Response};
use crate::config::ServiceModel;
use crate::sched::RequestClass;
use crate::util::ids::{
    AllocationId, FpgaId, JobId, LeaseToken, UserId,
};
use crate::util::json::Json;

/// A connected middleware client.
///
/// The client keeps the capability tokens returned by the
/// `alloc_*` RPCs and attaches them automatically to every mutating
/// call on the same allocation (`program*`, `stream`, `release`,
/// `migrate`) and to `job_*` calls on jobs it submitted — callers
/// work with allocation/job ids while the wire carries the token.
/// [`Client::set_lease_token`] / [`Client::set_job_token`] inject
/// tokens obtained elsewhere (other connections, the CLI `--lease`
/// flag, or deliberately wrong ones in tests).
pub struct Client {
    stream: TcpStream,
    /// Correlation-id counter for v2 requests.
    next_id: u64,
    /// alloc → capability token, learned from alloc responses.
    lease_tokens: BTreeMap<AllocationId, LeaseToken>,
    /// job → owner token, learned from submit responses.
    job_tokens: BTreeMap<JobId, LeaseToken>,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client, String> {
        let stream = TcpStream::connect_timeout(
            &addr,
            Duration::from_secs(5),
        )
        .map_err(|e| format!("connect {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .map_err(|e| e.to_string())?;
        Ok(Client {
            stream,
            next_id: 0,
            lease_tokens: BTreeMap::new(),
            job_tokens: BTreeMap::new(),
        })
    }

    /// The cached capability token for an allocation, if any.
    pub fn lease_token(&self, alloc: AllocationId) -> Option<LeaseToken> {
        self.lease_tokens.get(&alloc).copied()
    }

    /// Inject (or override) the token used for an allocation — for
    /// tokens handed over out of band, or to present a wrong one.
    pub fn set_lease_token(
        &mut self,
        alloc: AllocationId,
        token: LeaseToken,
    ) {
        self.lease_tokens.insert(alloc, token);
    }

    /// Inject (or override) the owner token used for a job.
    pub fn set_job_token(&mut self, job: JobId, token: LeaseToken) {
        self.job_tokens.insert(job, token);
    }

    /// Connect and negotiate the protocol via `hello`. Fails with
    /// [`ErrorCode::ProtocolMismatch`] when the windows don't
    /// overlap.
    pub fn connect_negotiated(
        addr: SocketAddr,
    ) -> Result<(Client, HelloResponse), ApiError> {
        let mut client =
            Client::connect(addr).map_err(ApiError::internal)?;
        let hello = client.hello()?;
        Ok((client, hello))
    }

    /// One raw protocol-1 round trip. Errors are strings: either
    /// transport ("io: …") or application (the server's error body).
    pub fn call(
        &mut self,
        method: &str,
        params: Json,
    ) -> Result<Json, String> {
        let req = Request::new(method, params);
        write_frame(&mut self.stream, &req.to_json())
            .map_err(|e| format!("io: {e}"))?;
        let frame = read_frame(&mut self.stream)
            .map_err(|e| format!("io: {e}"))?
            .ok_or_else(|| {
                "io: eof (server closed connection)".to_string()
            })?;
        Response::from_json(&frame)?.into_result()
    }

    /// One protocol-2 round trip: correlation id attached and
    /// verified, structured errors surfaced as [`ApiError`].
    pub fn call_v2(
        &mut self,
        method: &str,
        params: Json,
    ) -> Result<Json, ApiError> {
        self.next_id += 1;
        let id = self.next_id;
        let req = Request::v2(method, params, id);
        write_frame(&mut self.stream, &req.to_json())
            .map_err(|e| ApiError::internal(format!("io: {e}")))?;
        let frame = read_frame(&mut self.stream)
            .map_err(|e| ApiError::internal(format!("io: {e}")))?
            .ok_or_else(|| {
                ApiError::internal("io: eof (server closed connection)")
            })?;
        let resp =
            Response::from_json(&frame).map_err(ApiError::internal)?;
        if resp.id != Some(id) {
            return Err(ApiError::internal(format!(
                "response id mismatch: sent {id}, got {:?}",
                resp.id
            )));
        }
        resp.into_api_result()
    }

    // --------------------------------------------- typed: handshake

    /// Version-negotiating handshake.
    pub fn hello(&mut self) -> Result<HelloResponse, ApiError> {
        let body = self.call_v2(
            Method::Hello.name(),
            HelloRequest::ours().to_json(),
        )?;
        HelloResponse::from_json(&body)
    }

    // ------------------------------------------------ typed: users

    pub fn add_user(
        &mut self,
        name: &str,
    ) -> Result<AddUserResponse, ApiError> {
        let req = AddUserRequest {
            name: name.to_string(),
        };
        let body =
            self.call_v2(Method::AddUser.name(), req.to_json())?;
        AddUserResponse::from_json(&body)
    }

    // ----------------------------------------------- typed: status

    pub fn status(
        &mut self,
        fpga: FpgaId,
    ) -> Result<StatusResponse, ApiError> {
        let req = StatusRequest { fpga };
        let body = self.call_v2(Method::Status.name(), req.to_json())?;
        StatusResponse::from_json(&body)
    }

    pub fn monitor(&mut self) -> Result<MonitorResponse, ApiError> {
        let body = self.call_v2(
            Method::Monitor.name(),
            MonitorRequest.to_json(),
        )?;
        MonitorResponse::from_json(&body)
    }

    pub fn energy(&mut self) -> Result<EnergyResponse, ApiError> {
        let body = self
            .call_v2(Method::Energy.name(), EnergyRequest.to_json())?;
        EnergyResponse::from_json(&body)
    }

    pub fn db_dump(&mut self) -> Result<DbDumpResponse, ApiError> {
        let body = self
            .call_v2(Method::DbDump.name(), DbDumpRequest.to_json())?;
        DbDumpResponse::from_json(&body)
    }

    pub fn workload(
        &mut self,
        req: &WorkloadRequest,
    ) -> Result<WorkloadResponse, ApiError> {
        let body =
            self.call_v2(Method::Workload.name(), req.to_json())?;
        WorkloadResponse::from_json(&body)
    }

    // ------------------------------------------------ typed: leases

    /// Allocate vFPGAs: one by default, an atomic gang when the
    /// request's `regions > 1`. The returned capability token is
    /// cached for every member allocation.
    pub fn alloc_vfpga_with(
        &mut self,
        req: &AllocVfpgaRequest,
    ) -> Result<AllocVfpgaResponse, ApiError> {
        let body =
            self.call_v2(Method::AllocVfpga.name(), req.to_json())?;
        let resp = AllocVfpgaResponse::from_json(&body)?;
        for m in &resp.members {
            self.lease_tokens.insert(m.alloc, resp.lease);
        }
        Ok(resp)
    }

    /// Single-region allocation (the common case).
    pub fn alloc_vfpga(
        &mut self,
        user: UserId,
        model: Option<ServiceModel>,
        class: Option<RequestClass>,
    ) -> Result<AllocVfpgaResponse, ApiError> {
        self.alloc_vfpga_with(&AllocVfpgaRequest::single(
            user, model, class,
        ))
    }

    pub fn alloc_physical(
        &mut self,
        user: UserId,
    ) -> Result<AllocPhysicalResponse, ApiError> {
        let req = AllocPhysicalRequest { user };
        let body =
            self.call_v2(Method::AllocPhysical.name(), req.to_json())?;
        let resp = AllocPhysicalResponse::from_json(&body)?;
        self.lease_tokens.insert(resp.alloc, resp.lease);
        Ok(resp)
    }

    pub fn release(
        &mut self,
        alloc: AllocationId,
    ) -> Result<ReleaseResponse, ApiError> {
        let req = ReleaseRequest {
            alloc,
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::Release.name(), req.to_json())?;
        let resp = ReleaseResponse::from_json(&body)?;
        // The whole lease is gone server-side; drop every cached
        // member token for it.
        if let Some(token) = self.lease_tokens.remove(&alloc) {
            self.lease_tokens.retain(|_, t| *t != token);
        }
        Ok(resp)
    }

    pub fn program_core(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        core: &str,
    ) -> Result<ProgramCoreResponse, ApiError> {
        let req = ProgramCoreRequest {
            user,
            alloc,
            core: core.to_string(),
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::ProgramCore.name(), req.to_json())?;
        ProgramCoreResponse::from_json(&body)
    }

    pub fn migrate(
        &mut self,
        user: UserId,
        alloc: AllocationId,
    ) -> Result<MigrateResponse, ApiError> {
        let req = MigrateRequest {
            user,
            alloc,
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::Migrate.name(), req.to_json())?;
        MigrateResponse::from_json(&body)
    }

    // ------------------------------------------- typed: catalogues

    pub fn services(&mut self) -> Result<ServicesResponse, ApiError> {
        let body = self.call_v2(
            Method::Services.name(),
            ServicesRequest.to_json(),
        )?;
        ServicesResponse::from_json(&body)
    }

    pub fn cores(&mut self) -> Result<CoresResponse, ApiError> {
        let body =
            self.call_v2(Method::Cores.name(), CoresRequest.to_json())?;
        CoresResponse::from_json(&body)
    }

    // ------------------------------- typed: long-running operations

    /// Submit a streaming run; returns a job handle immediately.
    pub fn stream(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        core: &str,
        mults: u64,
    ) -> Result<JobSubmitResponse, ApiError> {
        let req = StreamRequest {
            user,
            alloc,
            core: core.to_string(),
            mults,
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::Stream.name(), req.to_json())?;
        let resp = JobSubmitResponse::from_json(&body)?;
        if let Some(t) = resp.lease {
            self.job_tokens.insert(resp.job, t);
        }
        Ok(resp)
    }

    /// Submit + wait: the old synchronous `stream` behavior.
    pub fn stream_sync(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        core: &str,
        mults: u64,
    ) -> Result<StreamOutcomeBody, ApiError> {
        let job = self.stream(user, alloc, core, mults)?.job;
        let result = self.job_wait_done(job)?;
        StreamOutcomeBody::from_json(&result)
    }

    /// Submit a full-bitstream configuration; returns a job handle.
    pub fn program_full(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        name: Option<&str>,
    ) -> Result<JobSubmitResponse, ApiError> {
        let req = ProgramFullRequest {
            user,
            alloc,
            name: name.map(String::from),
            lease: self.lease_token(alloc),
        };
        let body =
            self.call_v2(Method::ProgramFull.name(), req.to_json())?;
        let resp = JobSubmitResponse::from_json(&body)?;
        if let Some(t) = resp.lease {
            self.job_tokens.insert(resp.job, t);
        }
        Ok(resp)
    }

    /// Submit + wait: the old synchronous `program_full` behavior.
    pub fn program_full_sync(
        &mut self,
        user: UserId,
        alloc: AllocationId,
        name: Option<&str>,
    ) -> Result<ProgramFullResponse, ApiError> {
        let job = self.program_full(user, alloc, name)?.job;
        let result = self.job_wait_done(job)?;
        ProgramFullResponse::from_json(&result)
    }

    /// Submit a BAaaS service invocation; returns a job handle.
    pub fn invoke_service(
        &mut self,
        user: UserId,
        service: &str,
        mults: u64,
    ) -> Result<JobSubmitResponse, ApiError> {
        let req = InvokeServiceRequest {
            user,
            service: service.to_string(),
            mults,
        };
        let body =
            self.call_v2(Method::InvokeService.name(), req.to_json())?;
        let resp = JobSubmitResponse::from_json(&body)?;
        if let Some(t) = resp.lease {
            self.job_tokens.insert(resp.job, t);
        }
        Ok(resp)
    }

    /// Submit + wait: the old synchronous `invoke_service` behavior.
    pub fn invoke_service_sync(
        &mut self,
        user: UserId,
        service: &str,
        mults: u64,
    ) -> Result<StreamOutcomeBody, ApiError> {
        let job = self.invoke_service(user, service, mults)?.job;
        let result = self.job_wait_done(job)?;
        StreamOutcomeBody::from_json(&result)
    }

    // -------------------------------------------------- typed: jobs

    pub fn job_status(
        &mut self,
        job: JobId,
    ) -> Result<JobBody, ApiError> {
        let req = JobStatusRequest {
            job,
            lease: self.job_tokens.get(&job).copied(),
        };
        let body =
            self.call_v2(Method::JobStatus.name(), req.to_json())?;
        JobBody::from_json(&body)
    }

    /// Wait until the job is terminal (one server-side wait round;
    /// pass `timeout_s` to bound it, server default otherwise).
    pub fn job_wait(
        &mut self,
        job: JobId,
        timeout_s: Option<f64>,
    ) -> Result<JobBody, ApiError> {
        let req = JobWaitRequest {
            job,
            timeout_s,
            lease: self.job_tokens.get(&job).copied(),
        };
        let body =
            self.call_v2(Method::JobWait.name(), req.to_json())?;
        JobBody::from_json(&body)
    }

    /// Wait for a job and unwrap its `done` result, retrying through
    /// server-side wait timeouts (which are retryable by contract).
    pub fn job_wait_done(
        &mut self,
        job: JobId,
    ) -> Result<Json, ApiError> {
        loop {
            match self.job_wait(job, None) {
                Ok(body) => return body.into_done(),
                Err(e) if e.code == ErrorCode::Timeout => continue,
                Err(e) => return Err(e),
            }
        }
    }

    pub fn job_cancel(
        &mut self,
        job: JobId,
    ) -> Result<JobBody, ApiError> {
        let req = JobCancelRequest {
            job,
            lease: self.job_tokens.get(&job).copied(),
        };
        let body =
            self.call_v2(Method::JobCancel.name(), req.to_json())?;
        JobBody::from_json(&body)
    }

    // --------------------------------------------- typed: scheduler

    /// Scheduler queue/grant/reservation snapshot.
    pub fn sched_status(
        &mut self,
    ) -> Result<SchedStatusResponse, ApiError> {
        let body = self.call_v2(
            Method::SchedStatus.name(),
            SchedStatusRequest.to_json(),
        )?;
        SchedStatusResponse::from_json(&body)
    }

    /// Set (parts of) a tenant quota; unspecified fields keep their
    /// current values server-side. `max_vfpgas: 0` restores an
    /// unlimited cap; a negative `budget_s` clears the budget.
    pub fn quota_set(
        &mut self,
        req: &QuotaSetRequest,
    ) -> Result<QuotaResponse, ApiError> {
        let body =
            self.call_v2(Method::QuotaSet.name(), req.to_json())?;
        QuotaResponse::from_json(&body)
    }

    pub fn quota_get(
        &mut self,
        user: UserId,
    ) -> Result<QuotaResponse, ApiError> {
        let req = QuotaGetRequest { user };
        let body =
            self.call_v2(Method::QuotaGet.name(), req.to_json())?;
        QuotaResponse::from_json(&body)
    }

    /// Per-tenant usage rows + rendered operator table.
    pub fn usage_report(
        &mut self,
    ) -> Result<UsageReportResponse, ApiError> {
        let body = self.call_v2(
            Method::UsageReport.name(),
            UsageReportRequest.to_json(),
        )?;
        UsageReportResponse::from_json(&body)
    }

    /// Reserve vFPGA capacity for a tenant over a virtual-time window.
    pub fn reserve(
        &mut self,
        req: &ReserveRequest,
    ) -> Result<ReserveResponse, ApiError> {
        let body =
            self.call_v2(Method::Reserve.name(), req.to_json())?;
        ReserveResponse::from_json(&body)
    }

    pub fn cancel_reservation(
        &mut self,
        reservation: crate::util::ids::ReservationId,
    ) -> Result<CancelReservationResponse, ApiError> {
        let req = CancelReservationRequest { reservation };
        let body = self
            .call_v2(Method::CancelReservation.name(), req.to_json())?;
        CancelReservationResponse::from_json(&body)
    }

    // ------------------------------------------------- typed: agent

    pub fn agent_hello(
        &mut self,
    ) -> Result<AgentHelloResponse, ApiError> {
        let body = self.call_v2(
            Method::AgentHello.name(),
            AgentHelloRequest.to_json(),
        )?;
        AgentHelloResponse::from_json(&body)
    }

    pub fn agent_status(
        &mut self,
        fpga: FpgaId,
    ) -> Result<StatusResponse, ApiError> {
        let req = StatusRequest { fpga };
        let body =
            self.call_v2(Method::AgentStatus.name(), req.to_json())?;
        StatusResponse::from_json(&body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Minimal echo server for client-side tests. Speaks both
    /// envelope generations: v2 requests get their id echoed.
    fn echo_server() -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                std::thread::spawn(move || {
                    while let Ok(Some(frame)) = read_frame(&mut stream) {
                        let req = Request::from_json(&frame).unwrap();
                        let resp = if req.method == "fail" {
                            if req.proto.unwrap_or(1) >= 2 {
                                Response::failure(
                                    req.id,
                                    ApiError::new(
                                        ErrorCode::NoCapacity,
                                        "requested failure",
                                    ),
                                )
                            } else {
                                Response::error("requested failure")
                            }
                        } else if req.proto.unwrap_or(1) >= 2 {
                            Response::success_v2(req.id, req.params)
                        } else {
                            Response::success(req.params)
                        };
                        if write_frame(&mut stream, &resp.to_json()).is_err()
                        {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn call_roundtrips_params() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        let params = Json::obj(vec![("x", Json::from(7u64))]);
        let body = c.call("echo", params.clone()).unwrap();
        assert_eq!(body, params);
    }

    #[test]
    fn call_v2_checks_id_and_carries_codes() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        let params = Json::obj(vec![("x", Json::from(7u64))]);
        let body = c.call_v2("echo", params.clone()).unwrap();
        assert_eq!(body, params);
        let err = c.call_v2("fail", Json::obj(vec![])).unwrap_err();
        assert_eq!(err.code, ErrorCode::NoCapacity);
        assert!(err.retryable);
        assert_eq!(err.message, "requested failure");
    }

    #[test]
    fn application_errors_surface() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        assert_eq!(
            c.call("fail", Json::obj(vec![])),
            Err("requested failure".to_string())
        );
    }

    #[test]
    fn connect_refused_is_error() {
        // Port 1 on loopback is almost certainly closed.
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        assert!(Client::connect(addr).is_err());
    }

    #[test]
    fn sequential_calls_reuse_connection() {
        let addr = echo_server();
        let mut c = Client::connect(addr).unwrap();
        for i in 0..5u64 {
            let body = c
                .call("echo", Json::obj(vec![("i", Json::from(i))]))
                .unwrap();
            assert_eq!(body.get("i").as_u64(), Some(i));
        }
    }
}
